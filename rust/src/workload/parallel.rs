//! Parallel-strategy enumeration (paper §VI-A: "we iterate through all
//! combinations of TP, DP, PP, and micro-batch sizes that satisfy the
//! memory capacity constraint and select the best-performance parallel
//! strategy based on the evaluation results").
//!
//! A *chunk* is one (TP shard × PP stage × DP replica) of the model; the
//! Workload Compiler binds each chunk to an equal share of the system's
//! compute (Fig. 6).

use super::LlmSpec;

/// One point of the parallelism space.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ParallelStrategy {
    /// Tensor-parallel ways (shards attention heads / MLP columns).
    pub tp: usize,
    /// Pipeline-parallel stages (must divide layers evenly — §II-A).
    pub pp: usize,
    /// Data-parallel replicas.
    pub dp: usize,
    /// Micro-batch size in sequences.
    pub microbatch: usize,
}

impl ParallelStrategy {
    pub fn num_chunks(&self) -> usize {
        self.tp * self.pp * self.dp
    }

    /// Microbatches in flight per replica per step.
    pub fn microbatches_per_step(&self, spec: &LlmSpec) -> usize {
        (spec.batch_size / self.dp / self.microbatch).max(1)
    }

    /// 1F1B pipeline efficiency: mb / (mb + pp − 1).
    pub fn pipeline_efficiency(&self, spec: &LlmSpec) -> f64 {
        let mb = self.microbatches_per_step(spec) as f64;
        mb / (mb + self.pp as f64 - 1.0)
    }

    /// Layers per pipeline stage.
    pub fn layers_per_stage(&self, spec: &LlmSpec) -> usize {
        spec.layers / self.pp
    }
}

/// Memory-capacity description of the target system for the §VI-A
/// feasibility filter.
#[derive(Debug, Clone, Copy)]
pub struct SystemMemory {
    /// Total on-wafer SRAM across the system, bytes.
    pub sram_bytes: f64,
    /// Total stacked-DRAM capacity, bytes (0 for off-chip designs).
    pub stacking_bytes: f64,
    /// Total off-chip DRAM capacity, bytes.
    pub offchip_bytes: f64,
    /// Total cores in the system (chunks cannot outnumber cores).
    pub total_cores: usize,
}

impl SystemMemory {
    pub fn total_bytes(&self) -> f64 {
        self.sram_bytes + self.stacking_bytes + self.offchip_bytes
    }
}

fn divisors_of(n: usize, cap: usize) -> Vec<usize> {
    (1..=n.min(cap)).filter(|d| n % d == 0).collect()
}

fn pow2_up_to(n: usize) -> Vec<usize> {
    let mut v = vec![1usize];
    while *v.last().unwrap() * 2 <= n {
        let next = v.last().unwrap() * 2;
        v.push(next);
    }
    v
}

/// Per-chunk memory demand for *training*: full optimizer state of the
/// chunk's layer shard plus checkpointed activations of in-flight
/// microbatches.
pub fn train_chunk_bytes(spec: &LlmSpec, s: &ParallelStrategy) -> f64 {
    let state = spec.train_state_bytes() / (s.tp * s.pp) as f64;
    // 2-layer checkpoint granularity: boundary activations for half the
    // stage's layers, for up to `pp` in-flight microbatches (1F1B).
    let ckpt_layers = (s.layers_per_stage(spec) as f64 / 2.0).ceil();
    let act = spec.act_bytes_per_seq_layer() * s.microbatch as f64 * ckpt_layers
        / s.tp as f64
        * s.pp.min(4) as f64;
    state + act
}

/// Per-chunk memory demand for *inference* (weights + KV cache at batch).
pub fn infer_chunk_bytes(spec: &LlmSpec, s: &ParallelStrategy, batch: usize, mqa: bool) -> f64 {
    let weights = spec.param_bytes() / (s.tp * s.pp) as f64;
    let kv = spec.kv_cache_bytes_per_seq(mqa) * batch as f64 / (s.tp * s.pp) as f64;
    weights + kv
}

/// Enumerate feasible strategies (training). Capped to keep the §VI-A
/// iteration tractable: TP ≤ 64 and dividing heads, PP dividing layers,
/// DP a power of two dividing batch, microbatch a power of two.
pub fn enumerate_strategies(spec: &LlmSpec, mem: &SystemMemory) -> Vec<ParallelStrategy> {
    let mut out = Vec::new();
    let tps: Vec<usize> = pow2_up_to(spec.heads.min(64))
        .into_iter()
        .filter(|t| spec.heads % t == 0)
        .collect();
    let pps = divisors_of(spec.layers, 64);
    let dps: Vec<usize> = pow2_up_to(spec.batch_size.min(1 << 14))
        .into_iter()
        .filter(|d| spec.batch_size % d == 0)
        .collect();

    for &tp in &tps {
        for &pp in &pps {
            for &dp in &dps {
                let chunks = tp * pp * dp;
                if chunks > mem.total_cores {
                    continue;
                }
                let per_replica = spec.batch_size / dp;
                for &mb in &pow2_up_to(per_replica.min(64)) {
                    if per_replica % mb != 0 {
                        continue;
                    }
                    let s = ParallelStrategy {
                        tp,
                        pp,
                        dp,
                        microbatch: mb,
                    };
                    let demand = train_chunk_bytes(spec, &s) * chunks as f64;
                    if demand <= mem.total_bytes() {
                        out.push(s);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::benchmarks;

    fn mem_big() -> SystemMemory {
        SystemMemory {
            sram_bytes: 40e9,
            stacking_bytes: 1e12,
            offchip_bytes: 3e12,
            total_cores: 10_000,
        }
    }

    #[test]
    fn finds_strategies_for_small_model() {
        let spec = &benchmarks()[0];
        let ss = enumerate_strategies(spec, &mem_big());
        assert!(!ss.is_empty());
        // All returned strategies satisfy divisibility + memory.
        for s in &ss {
            assert_eq!(spec.layers % s.pp, 0);
            assert_eq!(spec.heads % s.tp, 0);
            assert_eq!(spec.batch_size % s.dp, 0);
            assert!(s.num_chunks() <= 10_000);
        }
    }

    #[test]
    fn tiny_memory_filters_everything() {
        let spec = &benchmarks()[9]; // 529B params
        let mem = SystemMemory {
            sram_bytes: 1e9,
            stacking_bytes: 0.0,
            offchip_bytes: 0.0,
            total_cores: 10_000,
        };
        assert!(enumerate_strategies(spec, &mem).is_empty());
    }

    #[test]
    fn pipeline_efficiency_shape() {
        let spec = &benchmarks()[0];
        let s1 = ParallelStrategy { tp: 1, pp: 1, dp: 1, microbatch: 8 };
        let s8 = ParallelStrategy { tp: 1, pp: 8, dp: 1, microbatch: 8 };
        assert_eq!(s1.pipeline_efficiency(spec), 1.0);
        let e8 = s8.pipeline_efficiency(spec);
        assert!(e8 < 1.0 && e8 > 0.5, "e8={e8}");
        // More microbatches -> better efficiency.
        let s8small = ParallelStrategy { tp: 1, pp: 8, dp: 1, microbatch: 1 };
        assert!(s8small.pipeline_efficiency(spec) > e8);
    }

    #[test]
    fn train_memory_scales_down_with_tp_pp() {
        let spec = &benchmarks()[7];
        let base = ParallelStrategy { tp: 1, pp: 1, dp: 1, microbatch: 1 };
        let split = ParallelStrategy { tp: 8, pp: 8, dp: 1, microbatch: 1 };
        assert!(train_chunk_bytes(spec, &split) < train_chunk_bytes(spec, &base) / 30.0);
    }

    #[test]
    fn infer_memory_mqa_helps() {
        let spec = &benchmarks()[7];
        let s = ParallelStrategy { tp: 8, pp: 1, dp: 1, microbatch: 1 };
        let full = infer_chunk_bytes(spec, &s, 32, false);
        let mqa = infer_chunk_bytes(spec, &s, 32, true);
        assert!(mqa < full);
    }

    #[test]
    fn prop_enumeration_feasible() {
        let specs = benchmarks();
        crate::util::prop::check(
            "enumerated strategies satisfy the memory constraint",
            |r| {
                let spec = specs[r.below(4)].clone(); // small models for speed
                let mem = SystemMemory {
                    sram_bytes: r.uniform(1e9, 100e9),
                    stacking_bytes: r.uniform(0.0, 2e12),
                    offchip_bytes: r.uniform(0.0, 4e12),
                    total_cores: r.range(100, 50_000),
                };
                (spec, mem)
            },
            |(spec, mem)| {
                for s in enumerate_strategies(spec, mem).iter().take(200) {
                    let demand = train_chunk_bytes(spec, s) * s.num_chunks() as f64;
                    if demand > mem.total_bytes() {
                        return Err(format!("{s:?} demand {demand:.2e} > cap"));
                    }
                }
                Ok(())
            },
        );
    }
}
