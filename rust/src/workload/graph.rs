//! Operator graphs (paper §VI-A step 1, Fig. 6a-b).
//!
//! The Workload Compiler segments the model into chunks and generates the
//! operator DAG of one chunk. For a GPT block the fwd graph is
//! LN → QKV → scores → softmax → context → proj(+res) → LN → MLP-up →
//! GeLU → MLP-down(+res); training appends explicit dgrad/wgrad matmuls.
//! All dims are *per TP shard* of one microbatch.

use crate::arch::constants as k;

use super::LlmSpec;

/// Which execution phase a graph models. Also the phase axis of the
/// evaluation engine ([`crate::eval::engine::EvalSpec`]) and of campaign
/// scenarios — `parse`/`name` below are the single source of truth for
/// the phase strings accepted by `theseus dse --phase` and scenario JSON.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Training fwd+bwd of one microbatch through one pipeline stage.
    Training,
    /// Inference prefill (full-sequence fwd).
    Prefill,
    /// Inference decode (one token per sequence, KV-cache reads).
    Decode,
}

impl Phase {
    pub const ALL: [Phase; 3] = [Phase::Training, Phase::Prefill, Phase::Decode];

    pub fn name(&self) -> &'static str {
        match self {
            Phase::Training => "training",
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
        }
    }

    pub fn parse(s: &str) -> Option<Phase> {
        Phase::ALL.into_iter().find(|p| p.name() == s)
    }

    /// [`Phase::parse`] with a human-oriented error naming the valid
    /// phases — CLI and scenario-JSON call sites print this and exit 1
    /// instead of silently falling back.
    pub fn parse_or_usage(s: &str) -> Result<Phase, String> {
        Phase::parse(s).ok_or_else(|| {
            let names: Vec<&str> = Phase::ALL.iter().map(Phase::name).collect();
            format!("unknown phase '{s}' — valid: {}", names.join(", "))
        })
    }

    pub fn is_inference(&self) -> bool {
        !matches!(self, Phase::Training)
    }
}

/// Operator kinds with their shard-local shapes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OpKind {
    /// Dense GEMM: (m × k) · (k × n).
    Matmul { m: usize, k: usize, n: usize },
    /// Batched GEMM (attention scores/context): `batch` independent
    /// (m × k)·(k × n) products.
    BatchMatmul { batch: usize, m: usize, k: usize, n: usize },
    /// Row softmax over `rows` × `cols`.
    Softmax { rows: usize, cols: usize },
    /// LayerNorm over `rows` × `cols`.
    LayerNorm { rows: usize, cols: usize },
    /// Pointwise op over `elems` elements (GeLU, residual add, ...).
    Elementwise { elems: usize },
    /// KV-cache streaming read of `bytes` (decode only; hits DRAM).
    KvRead { bytes: f64 },
}

impl OpKind {
    pub fn flops(&self) -> f64 {
        match *self {
            OpKind::Matmul { m, k, n } => 2.0 * m as f64 * k as f64 * n as f64,
            OpKind::BatchMatmul { batch, m, k, n } => {
                2.0 * batch as f64 * m as f64 * k as f64 * n as f64
            }
            OpKind::Softmax { rows, cols } => 5.0 * rows as f64 * cols as f64,
            OpKind::LayerNorm { rows, cols } => 8.0 * rows as f64 * cols as f64,
            OpKind::Elementwise { elems } => elems as f64,
            OpKind::KvRead { .. } => 0.0,
        }
    }

    /// Output tensor bytes.
    pub fn out_bytes(&self) -> f64 {
        let elems = match *self {
            OpKind::Matmul { m, n, .. } => m as f64 * n as f64,
            OpKind::BatchMatmul { batch, m, n, .. } => batch as f64 * m as f64 * n as f64,
            OpKind::Softmax { rows, cols } | OpKind::LayerNorm { rows, cols } => {
                rows as f64 * cols as f64
            }
            OpKind::Elementwise { elems } => elems as f64,
            OpKind::KvRead { bytes } => return bytes,
        };
        elems * k::BYTES_PER_ELEM
    }

    /// Weight bytes resident for the op (GEMM operands that persist).
    pub fn weight_bytes(&self) -> f64 {
        match *self {
            OpKind::Matmul { k, n, .. } => k as f64 * n as f64 * crate::arch::constants::BYTES_PER_ELEM,
            _ => 0.0,
        }
    }

    /// Whether the op is dominated by memory streaming rather than MACs.
    pub fn is_memory_bound_kind(&self) -> bool {
        matches!(
            self,
            OpKind::Softmax { .. }
                | OpKind::LayerNorm { .. }
                | OpKind::Elementwise { .. }
                | OpKind::KvRead { .. }
        )
    }
}

/// One operator node.
#[derive(Debug, Clone, Copy)]
pub struct Op {
    pub id: usize,
    pub kind: OpKind,
}

/// Dependency edge carrying `bytes` of activation between ops.
#[derive(Debug, Clone, Copy)]
pub struct Edge {
    pub src: usize,
    pub dst: usize,
    pub bytes: f64,
}

/// Operator DAG of one chunk (Fig. 6b). Ops are in a valid topological
/// order by construction.
#[derive(Debug, Clone, Default)]
pub struct OpGraph {
    pub ops: Vec<Op>,
    pub edges: Vec<Edge>,
}

impl OpGraph {
    fn push(&mut self, kind: OpKind, deps: &[usize]) -> usize {
        let id = self.ops.len();
        self.ops.push(Op { id, kind });
        for &d in deps {
            self.edges.push(Edge {
                src: d,
                dst: id,
                bytes: self.ops[d].kind.out_bytes(),
            });
        }
        id
    }

    pub fn total_flops(&self) -> f64 {
        self.ops.iter().map(|o| o.kind.flops()).sum()
    }

    pub fn total_edge_bytes(&self) -> f64 {
        self.edges.iter().map(|e| e.bytes).sum()
    }

    /// Resident weight bytes across all ops (per TP shard, per layer set).
    pub fn total_weight_bytes(&self) -> f64 {
        self.ops.iter().map(|o| o.kind.weight_bytes()).sum()
    }

    /// Verify the edge list is consistent with a topological node order.
    pub fn is_topo_ordered(&self) -> bool {
        self.edges.iter().all(|e| e.src < e.dst)
    }

    /// Build the operator graph of `n_layers` transformer layers for one
    /// microbatch of `mb_seqs` sequences, sharded over `tp` tensor-parallel
    /// ways. `phase` selects training (adds bwd), prefill, or decode
    /// (seq dim = 1 token, adds KV reads; `mqa` shrinks KV traffic).
    pub fn transformer_chunk(
        spec: &LlmSpec,
        n_layers: usize,
        mb_seqs: usize,
        tp: usize,
        phase: Phase,
        mqa: bool,
    ) -> OpGraph {
        let mut g = OpGraph::default();
        let h = spec.hidden;
        let tp = tp.max(1);
        let heads_shard = (spec.heads / tp).max(1);
        let d = spec.head_dim();
        // Token rows processed by this graph.
        let s = match phase {
            Phase::Decode => 1,
            _ => spec.seq_len,
        };
        let rows = mb_seqs * s;
        // Context length attended over.
        let ctx = spec.seq_len;

        let mut prev: usize = g.push(
            OpKind::LayerNorm { rows, cols: h },
            &[],
        );

        for _ in 0..n_layers {
            // --- attention ---
            let qkv = g.push(
                OpKind::Matmul {
                    m: rows,
                    k: h,
                    n: 3 * heads_shard * d,
                },
                &[prev],
            );
            let kv_deps = if phase == Phase::Decode {
                let kv_heads = if mqa { 1 } else { heads_shard };
                let bytes = 2.0
                    * kv_heads as f64
                    * ctx as f64
                    * d as f64
                    * k::BYTES_PER_ELEM
                    * mb_seqs as f64;
                let kv = g.push(OpKind::KvRead { bytes }, &[]);
                vec![qkv, kv]
            } else {
                vec![qkv]
            };
            let scores = g.push(
                OpKind::BatchMatmul {
                    batch: mb_seqs * heads_shard,
                    m: s,
                    k: d,
                    n: ctx,
                },
                &kv_deps,
            );
            let softmax = g.push(
                OpKind::Softmax {
                    rows: mb_seqs * heads_shard * s,
                    cols: ctx,
                },
                &[scores],
            );
            let context = g.push(
                OpKind::BatchMatmul {
                    batch: mb_seqs * heads_shard,
                    m: s,
                    k: ctx,
                    n: d,
                },
                &[softmax],
            );
            let proj = g.push(
                OpKind::Matmul {
                    m: rows,
                    k: heads_shard * d,
                    n: h,
                },
                &[context],
            );
            let res1 = g.push(OpKind::Elementwise { elems: rows * h }, &[proj, prev]);
            let ln2 = g.push(OpKind::LayerNorm { rows, cols: h }, &[res1]);

            // --- MLP ---
            let up = g.push(
                OpKind::Matmul {
                    m: rows,
                    k: h,
                    n: 4 * h / tp,
                },
                &[ln2],
            );
            let gelu = g.push(
                OpKind::Elementwise {
                    elems: rows * 4 * h / tp,
                },
                &[up],
            );
            let down = g.push(
                OpKind::Matmul {
                    m: rows,
                    k: 4 * h / tp,
                    n: h,
                },
                &[gelu],
            );
            let res2 = g.push(OpKind::Elementwise { elems: rows * h }, &[down, res1]);
            prev = res2;
        }

        if phase == Phase::Training {
            // Backward: for each fwd GEMM, a dgrad and a wgrad GEMM of the
            // same volume. We append them as a mirrored tail so the DAG
            // stays topologically ordered; memory-bound ops get a 2×
            // revisit (recompute under 2-layer checkpointing + grad).
            let fwd_ops: Vec<Op> = g.ops.clone();
            let mut tail_prev = prev;
            for op in fwd_ops.iter().rev() {
                match op.kind {
                    OpKind::Matmul { m, k: kk, n } => {
                        let dgrad = g.push(OpKind::Matmul { m, k: n, n: kk }, &[tail_prev]);
                        let _wgrad = g.push(OpKind::Matmul { m: kk, k: m, n }, &[dgrad]);
                        tail_prev = dgrad;
                    }
                    OpKind::BatchMatmul { batch, m, k: kk, n } => {
                        let dgrad = g.push(
                            OpKind::BatchMatmul { batch, m, k: n, n: kk },
                            &[tail_prev],
                        );
                        let _wgrad =
                            g.push(OpKind::BatchMatmul { batch, m: kk, k: m, n }, &[dgrad]);
                        tail_prev = dgrad;
                    }
                    OpKind::Softmax { rows, cols } => {
                        tail_prev = g.push(OpKind::Softmax { rows, cols }, &[tail_prev]);
                    }
                    OpKind::LayerNorm { rows, cols } => {
                        tail_prev = g.push(OpKind::LayerNorm { rows, cols }, &[tail_prev]);
                    }
                    OpKind::Elementwise { elems } => {
                        tail_prev = g.push(OpKind::Elementwise { elems }, &[tail_prev]);
                    }
                    OpKind::KvRead { .. } => {}
                }
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::benchmarks;

    fn spec() -> LlmSpec {
        benchmarks()[0].clone() // GPT-1.7B
    }

    #[test]
    fn topo_ordered_all_phases() {
        for phase in [Phase::Training, Phase::Prefill, Phase::Decode] {
            let g = OpGraph::transformer_chunk(&spec(), 2, 1, 2, phase, false);
            assert!(g.is_topo_ordered());
            assert!(!g.ops.is_empty());
        }
    }

    #[test]
    fn training_flops_triple_prefill() {
        let f = OpGraph::transformer_chunk(&spec(), 2, 1, 1, Phase::Prefill, false);
        let t = OpGraph::transformer_chunk(&spec(), 2, 1, 1, Phase::Training, false);
        let ratio = t.total_flops() / f.total_flops();
        assert!(ratio > 2.7 && ratio < 3.3, "ratio={ratio}");
    }

    #[test]
    fn fwd_flops_match_analytic() {
        // One full model fwd over one sequence ≈ fwd_flops_per_token × seq,
        // excluding embeddings (graph models transformer blocks only).
        let m = spec();
        let g = OpGraph::transformer_chunk(&m, m.layers, 1, 1, Phase::Prefill, false);
        let analytic = m.fwd_flops_per_token() * m.seq_len as f64;
        let rel = (g.total_flops() - analytic).abs() / analytic;
        assert!(rel < 0.15, "graph={:.3e} analytic={:.3e}", g.total_flops(), analytic);
    }

    #[test]
    fn tp_shards_flops() {
        let g1 = OpGraph::transformer_chunk(&spec(), 2, 1, 1, Phase::Prefill, false);
        let g4 = OpGraph::transformer_chunk(&spec(), 2, 1, 4, Phase::Prefill, false);
        let ratio = g1.total_flops() / g4.total_flops();
        assert!(ratio > 3.0 && ratio < 4.5, "ratio={ratio}");
    }

    #[test]
    fn decode_reads_kv_and_is_tiny() {
        let d = OpGraph::transformer_chunk(&spec(), 2, 4, 1, Phase::Decode, false);
        let p = OpGraph::transformer_chunk(&spec(), 2, 4, 1, Phase::Prefill, false);
        assert!(d.total_flops() < p.total_flops() / 100.0);
        let kv: f64 = d
            .ops
            .iter()
            .filter_map(|o| match o.kind {
                OpKind::KvRead { bytes } => Some(bytes),
                _ => None,
            })
            .sum();
        assert!(kv > 0.0);
        // MQA shrinks KV traffic by ~heads.
        let dm = OpGraph::transformer_chunk(&spec(), 2, 4, 1, Phase::Decode, true);
        let kvm: f64 = dm
            .ops
            .iter()
            .filter_map(|o| match o.kind {
                OpKind::KvRead { bytes } => Some(bytes),
                _ => None,
            })
            .sum();
        assert!((kv / kvm - spec().heads as f64).abs() < 1.0);
    }

    #[test]
    fn prop_graph_invariants() {
        crate::util::prop::check(
            "op graph edges reference valid ops, bytes positive",
            |r| {
                let layers = r.range(1, 4);
                let mb = r.range(1, 4);
                let tp = 1 << r.below(4);
                let phase = *r.choose(&[Phase::Training, Phase::Prefill, Phase::Decode]);
                (layers, mb, tp, phase)
            },
            |&(layers, mb, tp, phase)| {
                let g = OpGraph::transformer_chunk(&spec(), layers, mb, tp, phase, false);
                for e in &g.edges {
                    if e.src >= g.ops.len() || e.dst >= g.ops.len() {
                        return Err("dangling edge".into());
                    }
                    if e.bytes < 0.0 {
                        return Err("negative bytes".into());
                    }
                }
                if !g.is_topo_ordered() {
                    return Err("not topo ordered".into());
                }
                Ok(())
            },
        );
    }
}
