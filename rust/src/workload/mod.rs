//! LLM workload modeling (paper §VI-A inputs): benchmark model specs
//! (Table II), per-chunk operator graphs for training/prefill/decode, and
//! parallel-strategy enumeration (TP × PP × DP × microbatch).

pub mod graph;
pub mod models;
pub mod parallel;

pub use graph::{Op, OpGraph, OpKind, Phase};
pub use parallel::{enumerate_strategies, ParallelStrategy};

use crate::arch::constants as k;

/// A GPT-style benchmark model (Table II row).
#[derive(Debug, Clone, PartialEq)]
pub struct LlmSpec {
    pub name: String,
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    /// H100 count of the paper's area-matched GPU baseline.
    pub gpu_num: usize,
    /// Global training batch size (sequences).
    pub batch_size: usize,
    pub seq_len: usize,
    pub vocab: usize,
}

impl LlmSpec {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Total parameter count: 12·L·h² transformer core (QKV 3h², proj h²,
    /// MLP 8h²) + embeddings.
    pub fn param_count(&self) -> f64 {
        let h = self.hidden as f64;
        let l = self.layers as f64;
        12.0 * l * h * h + (self.vocab as f64) * h
    }

    /// Training FLOPs per token (fwd+bwd): the standard 6·N approximation
    /// plus attention-score terms.
    pub fn train_flops_per_token(&self) -> f64 {
        let h = self.hidden as f64;
        let l = self.layers as f64;
        let s = self.seq_len as f64;
        6.0 * self.param_count() + 12.0 * l * h * s
    }

    /// Forward-only FLOPs per token (inference prefill / decode step).
    pub fn fwd_flops_per_token(&self) -> f64 {
        self.train_flops_per_token() / 3.0
    }

    /// Parameter memory (bytes) at bf16.
    pub fn param_bytes(&self) -> f64 {
        self.param_count() * k::BYTES_PER_ELEM
    }

    /// Training state bytes per parameter: bf16 weight + bf16 grad + fp32
    /// Adam (m, v, master) = 2 + 2 + 12 (Megatron/ZeRO accounting).
    pub fn train_state_bytes(&self) -> f64 {
        self.param_count() * 16.0
    }

    /// KV-cache bytes per sequence at full context (both K and V, all
    /// layers). `mqa` = multi-query attention (one KV head).
    pub fn kv_cache_bytes_per_seq(&self, mqa: bool) -> f64 {
        let kv_heads = if mqa { 1.0 } else { self.heads as f64 };
        2.0 * self.layers as f64
            * self.seq_len as f64
            * kv_heads
            * self.head_dim() as f64
            * k::BYTES_PER_ELEM
    }

    /// Activation bytes per sequence per layer boundary (activation
    /// checkpointing at 2-layer granularity per §VIII-A keeps boundary
    /// tensors only).
    pub fn act_bytes_per_seq_layer(&self) -> f64 {
        self.seq_len as f64 * self.hidden as f64 * k::BYTES_PER_ELEM
    }
}

#[cfg(test)]
mod tests {
    use super::models::benchmarks;

    #[test]
    fn gpt3_param_count() {
        let m = &benchmarks()[7];
        let b = m.param_count() / 1e9;
        assert!((b - 175.0).abs() / 175.0 < 0.05, "gpt3={b}B");
    }

    #[test]
    fn train_flops_close_to_6n() {
        let m = &benchmarks()[7];
        let ratio = m.train_flops_per_token() / (6.0 * m.param_count());
        assert!(ratio > 1.0 && ratio < 1.15, "ratio={ratio}");
    }

    #[test]
    fn mqa_shrinks_kv_cache() {
        let m = &benchmarks()[0];
        let full = m.kv_cache_bytes_per_seq(false);
        let mqa = m.kv_cache_bytes_per_seq(true);
        assert!((full / mqa - m.heads as f64).abs() < 1e-9);
    }

    #[test]
    fn kv_cache_magnitude_gpt3() {
        // GPT-3, seq 2048, bf16: 2*96*2048*12288*2 ≈ 9.7 GB per sequence.
        let m = &benchmarks()[7];
        let gb = m.kv_cache_bytes_per_seq(false) / 1e9;
        assert!((gb - 9.66).abs() < 0.5, "kv={gb}GB");
    }
}
