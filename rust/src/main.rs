//! `theseus` CLI — leader entrypoint for the DSE framework.
//!
//! Subcommands:
//!   gen-noc-dataset   CA-simulate random chunks -> GNN training JSON
//!   models            print the Table II benchmark LLMs
//!   space             design-space summary (cardinality, sample validity)
//!   eval              evaluate one design point on one benchmark
//!   dse               run the explorer (random | mobo | mfmobo) on one
//!                     phase (--phase training|prefill|decode) at one
//!                     fidelity (--fidelity analytical|ca|gnn|gnn-test);
//!                     --fault-defect M evaluates candidates on defective
//!                     wafers (--fault-spares N, --fault-seed S)
//!   campaign          run a scenario matrix (--suite
//!                     paper|fault|hetero|wafer-sweep|serving |
//!                     --scenarios f.json), resumable with --resume,
//!                     shardable with --shard K/N and fusable with
//!                     --merge DIR,DIR,...; --progress prints per-row
//!                     completion ticks to stderr (artifacts unchanged);
//!                     the fault suite sweeps defect rate × spare rows
//!                     and digests the degradation curve per row; the
//!                     wafer-sweep suite sweeps fixed wafer counts and
//!                     digests scaling efficiency per row; the serving
//!                     suite replays request traces through the
//!                     discrete-event serving simulator and digests
//!                     TTFT/latency/goodput per row
//!   serve-sim         replay one request stream on the reference design
//!                     (--model, --batch, --wafers, --arrival, --rate,
//!                     --requests, --prompt, --output, --slo,
//!                     --scheduler, --seed, --mqa; --trace FILE replays a
//!                     recorded JSON trace, --dump FILE writes the
//!                     generated trace)
//!   baselines         characterize H100/WSE2/Dojo reference designs

use theseus::util::cli::Args;

fn main() {
    let args = Args::from_env();
    match args.command() {
        Some("gen-noc-dataset") => cmd_gen_dataset(&args),
        Some("models") => cmd_models(),
        Some("space") => cmd_space(&args),
        Some("eval") => cmd_eval(&args),
        Some("dse") => cmd_dse(&args),
        Some("campaign") => cmd_campaign(&args),
        Some("serve-sim") => cmd_serve_sim(&args),
        Some("baselines") => cmd_baselines(),
        _ => {
            eprintln!(
                "usage: theseus <gen-noc-dataset|models|space|eval|dse|campaign|serve-sim|baselines> [--flags]\n\
                 see README.md for the full flag reference"
            );
            std::process::exit(2);
        }
    }
}

fn cmd_gen_dataset(args: &Args) {
    let out = args.str("out", "artifacts/noc_dataset.json");
    let n = args.usize(
        "n",
        theseus::util::cli::env_usize("THESEUS_DATASET_N", 256),
    );
    let seed = args.u64("seed", theseus::util::cli::env_u64("THESEUS_DATASET_SEED", 2024));
    // --serial bypasses the pooled fan-out (identical output; useful for
    // timing baselines and single-core machines).
    let serial = args.has("serial");
    eprintln!(
        "generating {n} CA-simulated samples (seed {seed}{}) ...",
        if serial { ", serial" } else { "" }
    );
    let t0 = std::time::Instant::now();
    let result = if serial {
        theseus::noc_sim::dataset::gen_dataset_serial(n, seed)
    } else {
        theseus::noc_sim::dataset::gen_dataset(n, seed)
    };
    let doc = match result {
        Ok(doc) => doc,
        Err(e) => {
            eprintln!("gen-noc-dataset failed: CA simulation overran its budget: {e}");
            std::process::exit(1);
        }
    };
    // Loud-exit CLI contract: an unwritable --out is a user error, not a
    // panic (the generation work is already done at this point — say so).
    if let Err(e) = std::fs::write(&out, doc.to_string()) {
        eprintln!("gen-noc-dataset: cannot write {out}: {e}");
        std::process::exit(1);
    }
    eprintln!("wrote {out} in {:.1}s", t0.elapsed().as_secs_f64());
}

fn cmd_models() {
    use theseus::util::table::Table;
    let mut t = Table::new(
        "Table II — benchmark LLMs",
        &["no", "name", "params(B)", "layers", "hidden", "heads", "gpus", "batch"],
    );
    for (i, m) in theseus::workload::models::benchmarks().iter().enumerate() {
        t.row(&[
            i.to_string(),
            m.name.clone(),
            format!("{:.1}", m.param_count() / 1e9),
            m.layers.to_string(),
            m.hidden.to_string(),
            m.heads.to_string(),
            m.gpu_num.to_string(),
            m.batch_size.to_string(),
        ]);
    }
    t.print();
}

fn cmd_space(args: &Args) {
    use theseus::design_space;
    use theseus::util::rng::Rng;
    println!(
        "design-space grid cardinality: {:.3e} configurations",
        design_space::cardinality()
    );
    let trials = args.usize("trials", 2000);
    let mut rng = Rng::new(args.u64("seed", 1));
    let mut ok = 0usize;
    let mut why = std::collections::BTreeMap::<String, usize>::new();
    for _ in 0..trials {
        let p = design_space::sample_raw(&mut rng);
        match design_space::validate(&p) {
            Ok(_) => ok += 1,
            Err(e) => {
                let key = format!("{e}")
                    .split([':', '('])
                    .next()
                    .unwrap_or("other")
                    .trim()
                    .to_string();
                *why.entry(key).or_default() += 1;
            }
        }
    }
    println!(
        "validator: {ok}/{trials} raw samples valid ({:.1}%)",
        100.0 * ok as f64 / trials as f64
    );
    for (k, v) in why {
        println!("  rejected by {k}: {v}");
    }
}

fn cmd_eval(args: &Args) {
    let model = args.str("model", "175b");
    let spec = theseus::workload::models::find_or_usage(&model).unwrap_or_else(|e| {
        eprintln!("eval: {e}");
        std::process::exit(1);
    });
    let v = theseus::design_space::validate(&theseus::design_space::reference_point())
        .expect("reference point valid");
    let sys = if args.has("wafers") {
        theseus::eval::SystemConfig {
            validated: v,
            n_wafers: args.usize("wafers", 1),
            faults: None,
        }
    } else {
        theseus::eval::SystemConfig::area_matched(v, spec.gpu_num)
    };
    println!(
        "system: {} wafers of {}",
        sys.n_wafers,
        sys.validated.point.wsc.summary()
    );
    let noc = theseus::eval::Analytical;
    match theseus::eval::eval_training(&spec, &sys, &noc) {
        Some(r) => {
            println!(
                "training {}: {:.1} tokens/s  step {:.3}s  power {:.1} kW  strategy tp{} pp{} dp{} mb{}",
                spec.name,
                r.tokens_per_sec,
                r.step_time_s,
                r.power_w / 1e3,
                r.strategy.tp,
                r.strategy.pp,
                r.strategy.dp,
                r.strategy.microbatch
            );
        }
        None => println!("no feasible parallel strategy (memory constraint)"),
    }
    if let Some(r) = theseus::eval::eval_inference(&spec, &sys, 32, false, &noc) {
        println!(
            "inference: prefill {:.3}s decode {:.2}ms/tok {:.1} tokens/s [{}]",
            r.prefill_s,
            r.decode_step_s * 1e3,
            r.tokens_per_sec,
            r.residency
        );
    }
}

fn cmd_dse(args: &Args) {
    theseus::coordinator::run_from_cli(args);
}

/// `theseus campaign`: batch-run a scenario matrix (the paper's §IX
/// evaluation matrix via `--suite paper`, or a custom JSON file via
/// `--scenarios`), with per-scenario seeds derived deterministically from
/// `--seed` and artifacts under `--out`. `--resume` skips scenarios whose
/// `scenarios/<key>.json` already exists under `--out` (long CA-fidelity
/// campaigns survive kills without redoing finished work). `--shard K/N`
/// runs the deterministic 1-of-N slice of the matrix (scale-out across
/// machines); `--merge DIR,DIR,...` fuses shard output dirs back into one
/// campaign under `--out`, re-running only scenarios that are missing,
/// failed, or recorded under a changed spec.
fn cmd_campaign(args: &Args) {
    use theseus::coordinator::campaign;

    let scenarios = if let Some(file) = args.opt_str("scenarios") {
        let text = std::fs::read_to_string(&file).unwrap_or_else(|e| {
            eprintln!("campaign: cannot read {file}: {e}");
            std::process::exit(1);
        });
        let json = theseus::util::json::Json::parse(&text).unwrap_or_else(|e| {
            eprintln!("campaign: {file}: {e}");
            std::process::exit(1);
        });
        campaign::scenarios_from_json(&json).unwrap_or_else(|e| {
            eprintln!("campaign: {file}: {e}");
            std::process::exit(1);
        })
    } else {
        let suite = args.str("suite", "paper");
        match suite.as_str() {
            "paper" => campaign::paper_suite(),
            "fault" => campaign::fault_suite(),
            "hetero" => campaign::hetero_suite(),
            "wafer-sweep" => campaign::wafer_sweep_suite(),
            "serving" => campaign::serving_suite(),
            _ => {
                eprintln!(
                    "campaign: unknown suite '{suite}' — valid: paper, fault, hetero, \
                     wafer-sweep, serving"
                );
                std::process::exit(1);
            }
        }
    };
    if scenarios.is_empty() {
        eprintln!("campaign: no scenarios to run");
        std::process::exit(1);
    }
    let out = args.str("out", "artifacts/campaign");
    let shard = args.opt_str("shard").map(|s| {
        campaign::parse_shard(&s).unwrap_or_else(|e| {
            eprintln!("campaign: {e}");
            std::process::exit(1);
        })
    });
    let merge_dirs: Option<Vec<std::path::PathBuf>> = args.opt_str("merge").map(|list| {
        list.split(',')
            .map(str::trim)
            .filter(|d| !d.is_empty())
            .map(std::path::PathBuf::from)
            .collect()
    });
    if merge_dirs.is_some() && (shard.is_some() || args.bool("resume", false)) {
        // Merge probes its DIR list; a shard filter or an implicit --out
        // probe on top of that would silently change which scenarios run.
        eprintln!("campaign: --merge cannot be combined with --shard or --resume");
        std::process::exit(1);
    }
    let cfg = campaign::CampaignConfig {
        scenarios,
        seed: args.u64("seed", 2024),
        jobs: args.usize("jobs", 0),
        resume_from: args
            .bool("resume", false)
            .then(|| std::path::PathBuf::from(&out)),
        shard,
    };
    eprintln!(
        "campaign: {} scenarios (seed {}, jobs {}{}{}{})",
        cfg.scenarios.len(),
        cfg.seed,
        if cfg.jobs == 0 {
            "auto".to_string()
        } else {
            cfg.jobs.to_string()
        },
        if cfg.resume_from.is_some() {
            ", resuming"
        } else {
            ""
        },
        match cfg.shard {
            Some((k, n)) => format!(", shard {k}/{n}"),
            None => String::new(),
        },
        match &merge_dirs {
            Some(dirs) => format!(", merging {} dirs", dirs.len()),
            None => String::new(),
        }
    );
    let t0 = std::time::Instant::now();
    // --progress: per-row completion ticks on stderr. Side-channel only —
    // the campaign layer guarantees progress runs write byte-identical
    // artifacts to silent ones (the ci smoke leg diffs them).
    let tick = |done: usize, total: usize, key: &str| {
        eprintln!("campaign: [{done}/{total}] {key}");
    };
    let progress: Option<&(dyn Fn(usize, usize, &str) + Sync)> =
        args.has("progress").then_some(&tick);
    let result = match &merge_dirs {
        Some(dirs) => campaign::merge_campaign(&cfg, dirs),
        None => campaign::run_campaign_with_progress(&cfg, progress),
    }
    .unwrap_or_else(|e| {
        eprintln!("campaign: {e}");
        std::process::exit(1);
    });
    theseus::figures::campaign_summary(&result).print();

    campaign::write_artifacts(&result, std::path::Path::new(&out)).unwrap_or_else(|e| {
        eprintln!("campaign: writing artifacts under {out} failed: {e}");
        std::process::exit(1);
    });
    let errors = result.n_errors();
    let resumed = result.n_resumed();
    eprintln!(
        "campaign: {} ok ({resumed} resumed) / {errors} error rows in {:.1}s; artifacts under {out}",
        result.rows.len() - errors,
        t0.elapsed().as_secs_f64()
    );
    if errors == result.rows.len() {
        // Every scenario failed: surface it in the exit status.
        std::process::exit(1);
    }
}

/// `theseus serve-sim`: replay one request stream on the reference design
/// through the discrete-event serving simulator and print the serving
/// digest (the same [`theseus::serving::ServingMetrics`] the campaign
/// serializes per serving row). The trace is either generated (`--arrival
/// --rate --requests --prompt --output --seed`, deterministic per seed)
/// or loaded from a recorded JSON file (`--trace FILE`); `--dump FILE`
/// writes the generated trace for later replay.
fn cmd_serve_sim(args: &Args) {
    use theseus::serving;

    let model = args.str("model", "1.7");
    let spec = theseus::workload::models::find_or_usage(&model).unwrap_or_else(|e| {
        eprintln!("serve-sim: {e}");
        std::process::exit(1);
    });
    let v = theseus::design_space::validate(&theseus::design_space::reference_point())
        .expect("reference point valid");
    let sys = if args.has("wafers") {
        theseus::eval::SystemConfig {
            validated: v,
            n_wafers: args.usize("wafers", 1).max(1),
            faults: None,
        }
    } else {
        theseus::eval::SystemConfig::area_matched(v, spec.gpu_num)
    };
    let batch = args.usize("batch", 32);
    let mqa = args.has("mqa");
    let slo_s = args.f64("slo", 1.0);
    if slo_s <= 0.0 {
        eprintln!("serve-sim: --slo must be positive (TTFT SLO, seconds)");
        std::process::exit(1);
    }
    let scheduler = serving::SchedulerKind::parse_or_usage(&args.str("scheduler", "fcfs"))
        .unwrap_or_else(|e| {
            eprintln!("serve-sim: {e}");
            std::process::exit(1);
        });
    let seed = args.u64("seed", 2024);

    let trace = if let Some(file) = args.opt_str("trace") {
        serving::trace::load(&file).unwrap_or_else(|e| {
            eprintln!("serve-sim: {e}");
            std::process::exit(1);
        })
    } else {
        let arrival = serving::ArrivalProcess::parse_or_usage(&args.str("arrival", "poisson"))
            .unwrap_or_else(|e| {
                eprintln!("serve-sim: {e}");
                std::process::exit(1);
            });
        let rate = args.f64("rate", 4.0);
        if rate <= 0.0 {
            eprintln!("serve-sim: --rate must be positive (requests/s)");
            std::process::exit(1);
        }
        serving::trace::generate(
            arrival,
            rate,
            args.usize("requests", 64).max(1),
            args.usize("prompt", 512).max(1),
            args.usize("output", 128).max(1),
            seed,
        )
    };
    if let Some(dump) = args.opt_str("dump") {
        if let Err(e) = std::fs::write(&dump, serving::trace::to_json(&trace).to_pretty() + "\n") {
            eprintln!("serve-sim: cannot write {dump}: {e}");
            std::process::exit(1);
        }
        eprintln!("serve-sim: wrote {} requests to {dump}", trace.len());
    }

    let phase = theseus::workload::Phase::Decode;
    let espec = theseus::eval::engine::EvalSpec::inference(spec.clone(), phase, batch)
        .with_wafers(args.has("wafers").then(|| sys.n_wafers))
        .with_mqa(mqa);
    let engine = theseus::eval::engine::Engine::new(espec).unwrap_or_else(|e| {
        eprintln!("serve-sim: {e}");
        std::process::exit(1);
    });
    println!(
        "system: {} wafers of {}; {} requests via {} scheduler",
        sys.n_wafers,
        sys.validated.point.wsc.summary(),
        trace.len(),
        scheduler.name()
    );
    let metrics = theseus::serving::evaluate(&engine, &sys, &trace, scheduler, slo_s)
        .unwrap_or_else(|e| {
            eprintln!("serve-sim: {e}");
            std::process::exit(1);
        });
    theseus::figures::serving_summary(&metrics).print();
}

fn cmd_baselines() {
    for (name, p) in [
        ("WSE2-like", theseus::baselines::wse2_like()),
        ("Dojo-like", theseus::baselines::dojo_like()),
    ] {
        let v = theseus::baselines::force_validate(&p);
        println!(
            "{name}: peak {:.2} PFLOPS, area {:.0} mm2, yield {:.3}, power cap use {:.1} kW",
            v.phys.peak_flops / 1e15,
            v.phys.area_mm2,
            v.phys.wafer_yield,
            v.phys.peak_power_w / 1e3
        );
    }
    let g = theseus::baselines::gpu::h100();
    println!(
        "H100: {:.0} TFLOPS bf16, {:.2} TB/s HBM, {:.0} GB, {:.0} W, {:.0} mm2",
        g.peak_flops / 1e12,
        g.hbm_bw / 1e12,
        g.hbm_cap / 1e9,
        g.tdp_w,
        g.die_mm2
    );
}
