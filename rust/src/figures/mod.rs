//! Figure/table regenerators (paper §VIII–IX): each function reproduces
//! one evaluation artifact of the paper as a [`Table`] (+ JSON rows via the
//! bench harness). Benches under `rust/benches/` are thin wrappers; tests
//! smoke each generator at miniature scale.

pub mod campaign;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod serving;

pub use campaign::campaign_summary;
pub use serving::serving_summary;
pub use fig7::fig7_eval_comparison;
pub use fig8::fig8_explorer_comparison;
pub use fig9::{fig10_reticle_granularity, fig9_core_granularity};
pub use fig11::fig11_inference_speedup;
pub use fig12::fig12_hetero_speedup;
pub use fig13::fig13_design_space;
