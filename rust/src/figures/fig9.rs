//! Fig. 9 — core-granularity tradeoffs and Fig. 10 — reticle-granularity
//! tradeoffs (paper §IX-A/B/C).
//!
//! For each core compute capability (mac grid point) we sample the other
//! parameters, keep validated points, evaluate training, and report the
//! best throughput and best (lowest) EDP — split by integration style for
//! the Fig. 9 die-stitching vs InFO-SoW comparison. Fig. 10 buckets by
//! reticle peak FLOPS and reports the reticle-area fraction of optima.

use crate::arch::IntegrationStyle;
use crate::design_space::{self, candidates, DesignPoint};
use crate::eval::{eval_training, SystemConfig};
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::workload::models;

pub struct Fig9Row {
    pub core_gflops: f64,
    pub style: IntegrationStyle,
    pub best_throughput: f64,
    pub best_edp: f64,
    pub valid_points: usize,
}

/// Sample `per_grid` configs for each (mac_num, style), evaluate training
/// on benchmark `bi`, keep the best.
pub fn fig9_core_granularity(bi: usize, per_grid: usize, seed: u64) -> (Table, Vec<Fig9Row>) {
    let spec = models::benchmarks()[bi].clone();
    let mut rng = Rng::new(seed);
    let mut rows = Vec::new();

    for &mac in &candidates::MAC_NUM {
        for style in IntegrationStyle::ALL {
            let mut best_t = 0.0f64;
            let mut best_edp = f64::INFINITY;
            let mut valid = 0usize;
            for _ in 0..per_grid {
                let Some(p) = sample_with(&mut rng, |p: &mut DesignPoint| {
                    p.wsc.reticle.core.mac_num = mac;
                    p.wsc.integration = style;
                }) else {
                    continue;
                };
                valid += 1;
                let sys = SystemConfig::area_matched(p.clone(), spec.gpu_num);
                if let Some(r) = eval_training(&spec, &sys, &crate::eval::Analytical) {
                    best_t = best_t.max(r.tokens_per_sec);
                    best_edp = best_edp.min(r.edp);
                }
            }
            rows.push(Fig9Row {
                core_gflops: 2.0 * mac as f64, // GFLOPS at 1 GHz
                style,
                best_throughput: best_t,
                best_edp,
                valid_points: valid,
            });
        }
    }

    let mut t = Table::new(
        &format!("Fig. 9 — core granularity ({}, training)", spec.name),
        &["core GFLOPS", "integration", "best tokens/s", "best EDP (J*s)", "valid pts"],
    );
    for r in &rows {
        t.row(&[
            format!("{:.0}", r.core_gflops),
            r.style.name().to_string(),
            format!("{:.1}", r.best_throughput),
            if r.best_edp.is_finite() {
                format!("{:.3e}", r.best_edp)
            } else {
                "-".to_string()
            },
            r.valid_points.to_string(),
        ]);
    }
    (t, rows)
}

fn sample_with(
    rng: &mut Rng,
    fix: impl Fn(&mut DesignPoint),
) -> Option<crate::design_space::Validated> {
    for _ in 0..300 {
        let mut p = design_space::sample_raw(rng);
        fix(&mut p);
        if let Ok(v) = design_space::validate(&p) {
            return Some(v);
        }
    }
    None
}

pub struct Fig10Row {
    pub reticle_tflops: f64,
    pub core_gflops: f64,
    pub array: (usize, usize),
    pub throughput: f64,
    pub area_fraction: f64,
}

/// Fig. 10: sweep (core granularity × array size) under the reticle area
/// constraint; report throughput per reticle granularity and the area
/// fraction of the best designs (paper: optima at 50–60 % of the limit).
pub fn fig10_reticle_granularity(bi: usize, seed: u64) -> (Table, Vec<Fig10Row>) {
    let spec = models::benchmarks()[bi].clone();
    let mut rng = Rng::new(seed);
    let mut rows: Vec<Fig10Row> = Vec::new();

    for &mac in &[128usize, 256, 512, 1024, 2048] {
        for &dim in &[4usize, 6, 8, 10, 12, 14, 16, 20] {
            let Some(v) = sample_with(&mut rng, |p| {
                p.wsc.reticle.core.mac_num = mac;
                p.wsc.reticle.array_h = dim;
                p.wsc.reticle.array_w = dim;
            }) else {
                continue;
            };
            let sys = SystemConfig::area_matched(v.clone(), spec.gpu_num);
            let Some(r) = eval_training(&spec, &sys, &crate::eval::Analytical) else {
                continue;
            };
            rows.push(Fig10Row {
                reticle_tflops: v.point.wsc.reticle.peak_flops() / 1e12,
                core_gflops: 2.0 * mac as f64,
                array: (dim, dim),
                throughput: r.tokens_per_sec,
                area_fraction: v.phys.reticle.area_mm2
                    / crate::arch::constants::RETICLE_AREA_MM2,
            });
        }
    }
    rows.sort_by(|a, b| a.reticle_tflops.total_cmp(&b.reticle_tflops));

    let mut t = Table::new(
        &format!("Fig. 10 — reticle granularity ({}, training)", spec.name),
        &["reticle TFLOPS", "core GFLOPS", "array", "tokens/s", "reticle area frac"],
    );
    for r in &rows {
        t.row(&[
            format!("{:.1}", r.reticle_tflops),
            format!("{:.0}", r.core_gflops),
            format!("{}x{}", r.array.0, r.array.1),
            format!("{:.1}", r.throughput),
            format!("{:.2}", r.area_fraction),
        ]);
    }
    (t, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_smoke() {
        let (_, rows) = fig9_core_granularity(0, 2, 3);
        assert_eq!(rows.len(), candidates::MAC_NUM.len() * 2);
        assert!(rows.iter().any(|r| r.best_throughput > 0.0));
    }

    #[test]
    fn fig10_smoke() {
        let (_, rows) = fig10_reticle_granularity(0, 3);
        assert!(!rows.is_empty());
        for r in &rows {
            assert!(r.area_fraction <= 1.0 + 1e-9);
        }
    }
}
