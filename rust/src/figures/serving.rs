//! Serving-digest table: the metric readout `theseus serve-sim` prints
//! after replaying a request trace on a design. Rendered from the same
//! [`ServingMetrics`] digest the campaign serializes per serving row
//! ([`crate::coordinator::campaign::serving_row_metrics`]), so table and
//! artifact cannot drift.

use crate::serving::ServingMetrics;
use crate::util::table::Table;

/// Render one serving digest as a two-column metric table.
pub fn serving_summary(m: &ServingMetrics) -> Table {
    let mut t = Table::new(
        &format!(
            "Serving digest — {} requests over {:.2}s",
            m.completed, m.makespan_s
        ),
        &["metric", "value"],
    );
    t.row(&["completed requests".to_string(), m.completed.to_string()]);
    t.row(&[
        "output tokens/s".to_string(),
        format!("{:.1}", m.tokens_per_sec),
    ]);
    t.row(&["TTFT p50".to_string(), format!("{:.1}ms", 1e3 * m.ttft_p50_s)]);
    t.row(&["TTFT p99".to_string(), format!("{:.1}ms", 1e3 * m.ttft_p99_s)]);
    t.row(&[
        "latency p50".to_string(),
        format!("{:.1}ms", 1e3 * m.latency_p50_s),
    ]);
    t.row(&[
        "latency p99".to_string(),
        format!("{:.1}ms", 1e3 * m.latency_p99_s),
    ]);
    t.row(&[
        format!("goodput (TTFT <= {:.0}ms)", 1e3 * m.slo_s),
        format!("{:.2} req/s", m.goodput_per_sec),
    ]);
    t.row(&["makespan".to_string(), format!("{:.2}s", m.makespan_s)]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::RequestOutcome;

    #[test]
    fn serving_summary_renders_every_digest_metric() {
        let outcomes = vec![
            RequestOutcome {
                id: 0,
                arrival_s: 0.0,
                first_token_s: 0.2,
                finish_s: 1.0,
                output_tokens: 16,
            },
            RequestOutcome {
                id: 1,
                arrival_s: 0.5,
                first_token_s: 1.5,
                finish_s: 2.5,
                output_tokens: 16,
            },
        ];
        let m = ServingMetrics::digest(&outcomes, 1.0).unwrap();
        let rendered = serving_summary(&m).render();
        assert!(rendered.contains("Serving digest"), "{rendered}");
        for label in [
            "completed requests",
            "output tokens/s",
            "TTFT p50",
            "TTFT p99",
            "latency p50",
            "latency p99",
            "goodput",
            "makespan",
        ] {
            assert!(rendered.contains(label), "missing {label}: {rendered}");
        }
    }
}
