//! Fig. 8 — optimization-results comparison: random search vs MOBO vs
//! MFMOBO hypervolume-vs-iteration curves (paper §VIII-C), plus the
//! convergence-speedup summary (the "2.1× faster to the same hypervolume,
//! +42 % HV at equal iterations" claims).

use crate::coordinator::ref_power_for;
use crate::eval::engine::{Engine, EvalSpec, Fidelity};
use crate::explorer::{mfmobo, mobo, random_search, BoConfig, MfConfig};
use crate::util::stats;
use crate::util::table::Table;
use crate::workload::models;

pub struct Fig8Result {
    pub benchmark: String,
    /// Mean HV per evaluation index, per explorer.
    pub random_hv: Vec<f64>,
    pub mobo_hv: Vec<f64>,
    pub mfmobo_hv: Vec<f64>,
    /// MFMOBO speedup to reach MOBO's final HV (x fewer evaluations).
    pub convergence_speedup: f64,
    /// HV improvement of MFMOBO over MOBO at equal evaluation count.
    pub hv_gain: f64,
}

fn mean_curves(curves: &[Vec<f64>]) -> Vec<f64> {
    let len = curves.iter().map(|c| c.len()).min().unwrap_or(0);
    (0..len)
        .map(|i| stats::mean(&curves.iter().map(|c| c[i]).collect::<Vec<_>>()))
        .collect()
}

/// Run the comparison for the given Table II benchmark indices.
/// `iters` = evaluations after init; `repeats` averages over seeds.
/// `fidelity` names the high-fidelity engine from the registry (matches
/// §VIII-C: high fidelity for MOBO/random, analytical + high inside
/// MFMOBO); an unavailable backend (e.g. `gnn` without artifacts) falls
/// back to analytical with a stderr note.
pub fn fig8_explorer_comparison(
    benchmarks: &[usize],
    iters: usize,
    repeats: usize,
    fidelity: Fidelity,
) -> (Table, Vec<Fig8Result>) {
    let specs = models::benchmarks();
    // The gnn fidelity loads (and PJRT-compiles) its artifact ONCE and
    // shares it across the per-benchmark engines.
    let shared_gnn = if fidelity == Fidelity::Gnn {
        match crate::runtime::GnnModel::load_default() {
            Ok(m) => Some(std::sync::Arc::new(m)),
            Err(e) => {
                crate::util::warn::warn_once(
                    "fig8-gnn",
                    &format!("fig8: fidelity 'gnn' unavailable: {e}; high fidelity = analytical"),
                );
                None
            }
        }
    } else {
        None
    };
    let mut results = Vec::new();

    for &bi in benchmarks {
        let spec = specs[bi].clone();
        let low = Engine::analytical_training(spec.clone());
        let high = match (&shared_gnn, fidelity) {
            (Some(m), _) => Engine::with_gnn_model(EvalSpec::training(spec.clone()), m.clone()),
            (None, Fidelity::Gnn) => Engine::analytical_training(spec.clone()),
            // Engine::new only errs for Fidelity::Gnn without a model (that
            // arm matched above) — but if the invariant ever breaks, warn
            // and degrade to analytical instead of panicking mid-figure.
            (None, f) => match Engine::new(EvalSpec::training(spec.clone()).with_fidelity(f)) {
                Ok(engine) => engine,
                Err(e) => {
                    crate::util::warn::warn_once(
                        "fig8-backend",
                        &format!(
                            "fig8: fidelity '{}' unavailable: {e}; high fidelity = analytical",
                            f.name()
                        ),
                    );
                    Engine::analytical_training(spec.clone())
                }
            },
        };
        let ref_power = ref_power_for(&spec);

        let mut r_curves = Vec::new();
        let mut m_curves = Vec::new();
        let mut f_curves = Vec::new();
        for rep in 0..repeats {
            let cfg = BoConfig {
                iters,
                init: 6,
                pool: 48,
                mc_samples: 32,
                ref_power,
                seed: 100 + rep as u64,
                sample_tries: 3000,
            };
            r_curves.push(random_search(&high, &cfg).hv_history);
            m_curves.push(mobo(&high, &cfg).hv_history);
            // MFMOBO splits the same budget: ~40% low-fidelity trials.
            let n1 = (iters * 2) / 5;
            let mf = MfConfig {
                base: BoConfig {
                    iters: iters - n1,
                    ..cfg.clone()
                },
                n1,
                d0: 3,
                d1: 3,
                k: (n1 / 4).max(2),
            };
            f_curves.push(mfmobo(&high, &low, &mf).hv_history);
        }
        let random_hv = mean_curves(&r_curves);
        let mobo_hv = mean_curves(&m_curves);
        let mfmobo_hv = mean_curves(&f_curves);

        // Convergence speedup: evaluations MOBO took to its final HV vs
        // evaluations MFMOBO took to the same HV.
        let target = mobo_hv.last().copied().unwrap_or(0.0);
        let mobo_iters = mobo_hv.len();
        let mf_iters = mfmobo_hv
            .iter()
            .position(|&h| h >= target)
            .map(|i| i + 1)
            .unwrap_or(mfmobo_hv.len());
        let convergence_speedup = mobo_iters as f64 / mf_iters as f64;
        let at = mobo_hv.len().min(mfmobo_hv.len()).saturating_sub(1);
        let hv_gain = if mobo_hv[at] > 0.0 {
            mfmobo_hv[at] / mobo_hv[at] - 1.0
        } else {
            0.0
        };

        results.push(Fig8Result {
            benchmark: spec.name.clone(),
            random_hv,
            mobo_hv,
            mfmobo_hv,
            convergence_speedup,
            hv_gain,
        });
    }

    let mut t = Table::new(
        "Fig. 8 — explorer comparison (mean hypervolume, final / convergence)",
        &[
            "benchmark",
            "HV random",
            "HV mobo",
            "HV mfmobo",
            "mfmobo speedup",
            "HV gain vs mobo",
        ],
    );
    for r in &results {
        t.row(&[
            r.benchmark.clone(),
            format!("{:.3e}", r.random_hv.last().copied().unwrap_or(0.0)),
            format!("{:.3e}", r.mobo_hv.last().copied().unwrap_or(0.0)),
            format!("{:.3e}", r.mfmobo_hv.last().copied().unwrap_or(0.0)),
            format!("{:.2}x", r.convergence_speedup),
            format!("{:+.0}%", r.hv_gain * 100.0),
        ]);
    }
    (t, results)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_smoke_tiny() {
        let (t, rs) = fig8_explorer_comparison(&[0], 4, 1, Fidelity::Analytical);
        assert_eq!(rs.len(), 1);
        assert!(rs[0].random_hv.iter().all(|&h| h >= 0.0));
        assert!(t.render().contains("Fig. 8"));
    }
}
