//! Fig. 7 — evaluation speedup and accuracy: CA simulation vs the
//! analytical model vs GNN-based evaluation, across workload scales.
//!
//! For each benchmark workload we generate a set of random WSC chunk
//! configurations, measure per-evaluation wall time of each method, and
//! compare chunk-latency estimates against CA ground truth (error % and
//! Kendall's τ — the Fig. 7b metrics).

use crate::arch::{CoreConfig, Dataflow};
use crate::bench;
use crate::compiler::compile_chunk;
use crate::eval::engine::Fidelity;
use crate::eval::op_level::{chunk_latency, NocModel};
use crate::eval::NocEstimator;
use crate::noc_sim;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::table::Table;
use crate::workload::{models, OpGraph, Phase};

pub struct Fig7Row {
    pub benchmark: String,
    pub ca_ms: f64,
    pub analytical_ms: f64,
    pub gnn_ms: f64,
    pub ana_err: f64,
    pub gnn_err: f64,
    pub ana_kt: f64,
    pub gnn_kt: f64,
}

/// Run the comparison over `n_benchmarks` Table II models (small end) with
/// `configs_per` random configurations each. The high-fidelity column is
/// named by the [`Fidelity`] registry (`gnn` for the PJRT model, `gnn-test`
/// for the in-process pseudo-GNN); `None` — or a registry entry whose
/// backend is unavailable (e.g. `gnn` without artifacts, reported on
/// stderr) — reports the analytical model only. A CA simulation budget
/// overrun propagates as [`noc_sim::SimError`].
pub fn fig7_eval_comparison(
    n_benchmarks: usize,
    configs_per: usize,
    high: Option<Fidelity>,
    seed: u64,
) -> Result<(Table, Vec<Fig7Row>), noc_sim::SimError> {
    let est: Option<Box<dyn NocEstimator>> = high.and_then(|f| match f.per_chunk_estimator() {
        Ok(e) => Some(e),
        Err(e) => {
            crate::util::warn::warn_once(
                "fig7-highfi",
                &format!("fig7: {e}; high-fidelity columns omitted"),
            );
            None
        }
    });
    let gnn = est.as_deref();
    let specs = models::benchmarks();
    let mut rows = Vec::new();
    let mut rng = Rng::new(seed);

    for spec in specs.iter().take(n_benchmarks) {
        let mut ca_lat = Vec::new();
        let mut ana_lat = Vec::new();
        let mut gnn_lat = Vec::new();
        let mut ca_time = Vec::new();
        let mut ana_time = Vec::new();
        let mut gnn_time = Vec::new();

        for _ in 0..configs_per {
            // Random small chunk config (the op-level evaluation scale).
            let core = CoreConfig {
                dataflow: *rng.choose(&Dataflow::ALL),
                mac_num: *rng.choose(&[128usize, 256, 512, 1024]),
                buffer_kb: 128,
                buffer_bw_bits: 256,
                noc_bw_bits: *rng.choose(&[128usize, 256, 512]),
            };
            let h = rng.range(3, 8);
            let w = rng.range(3, 8);
            let mut small = spec.clone();
            // Scale the per-chunk sequence with model size so bigger
            // benchmarks stress the NoC more (Fig. 7a's x-axis).
            small.seq_len = 32 + 16 * (spec.layers / 24).min(8);
            let g = OpGraph::transformer_chunk(&small, 1, 1, 8, Phase::Prefill, false);
            let chunk = compile_chunk(&g, h, w, &core);

            // CA ground truth.
            let (stats_ca, t_ca) = bench::time_once(|| {
                noc_sim::simulate_chunk_result(
                    &chunk,
                    core.noc_bw_bits,
                    &|op| {
                        crate::eval::tile::eval_tile_cached(&chunk.assignments[op], &core, 1.0)
                            .cycles
                            .ceil() as u64
                    },
                    300_000_000,
                )
            });
            let stats_ca = stats_ca?;
            ca_lat.push(stats_ca.cycles as f64);
            ca_time.push(t_ca);

            // Analytical.
            let (r_ana, t_ana) =
                bench::time_once(|| chunk_latency(&chunk, &core, 1.0, NocModel::Analytical));
            ana_lat.push(r_ana.cycles);
            ana_time.push(t_ana);

            // GNN (Eq. 6 reconstruction from predicted waits).
            if let Some(gnn) = gnn {
                let (r_gnn, t_gnn) = bench::time_once(|| {
                    match gnn.link_waits(&chunk, &core) {
                        Some(waits) => {
                            chunk_latency(&chunk, &core, 1.0, NocModel::LinkWaits(&waits))
                        }
                        None => chunk_latency(&chunk, &core, 1.0, NocModel::Analytical),
                    }
                });
                gnn_lat.push(r_gnn.cycles);
                gnn_time.push(t_gnn);
            }
        }

        let has_gnn = !gnn_lat.is_empty();
        rows.push(Fig7Row {
            benchmark: spec.name.clone(),
            ca_ms: stats::mean(&ca_time) * 1e3,
            analytical_ms: stats::mean(&ana_time) * 1e3,
            gnn_ms: if has_gnn { stats::mean(&gnn_time) * 1e3 } else { f64::NAN },
            ana_err: stats::mape(&ana_lat, &ca_lat),
            gnn_err: if has_gnn { stats::mape(&gnn_lat, &ca_lat) } else { f64::NAN },
            ana_kt: stats::kendall_tau(&ana_lat, &ca_lat),
            gnn_kt: if has_gnn {
                stats::kendall_tau(&gnn_lat, &ca_lat)
            } else {
                f64::NAN
            },
        });
    }

    let mut t = Table::new(
        "Fig. 7 — evaluation speedup (a) and accuracy (b) vs CA simulation",
        &[
            "benchmark",
            "CA ms",
            "ana ms",
            "gnn ms",
            "speedup(ana)",
            "speedup(gnn)",
            "err%(ana)",
            "err%(gnn)",
            "KT(ana)",
            "KT(gnn)",
        ],
    );
    for r in &rows {
        t.row(&[
            r.benchmark.clone(),
            format!("{:.2}", r.ca_ms),
            format!("{:.4}", r.analytical_ms),
            format!("{:.3}", r.gnn_ms),
            format!("{:.0}x", r.ca_ms / r.analytical_ms),
            format!("{:.0}x", r.ca_ms / r.gnn_ms),
            format!("{:.1}", r.ana_err * 100.0),
            format!("{:.1}", r.gnn_err * 100.0),
            format!("{:.2}", r.ana_kt),
            format!("{:.2}", r.gnn_kt),
        ]);
    }
    Ok((t, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_smoke_analytical_only() {
        let (t, rows) =
            fig7_eval_comparison(1, 3, None, 5).expect("CA simulation within budget");
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        // The analytical model must be at least 10x faster than CA sim.
        assert!(r.ca_ms / r.analytical_ms > 10.0, "speedup too small");
        // And rank-correlate positively with ground truth.
        assert!(r.ana_kt > 0.0, "kt={}", r.ana_kt);
        assert!(t.render().contains("Fig. 7"));
    }

    #[test]
    fn fig7_pseudo_gnn_columns_from_registry() {
        // The gnn-test registry entry drives the high-fidelity columns
        // without artifacts (and the real `gnn` entry degrades to
        // analytical-only when unavailable instead of failing).
        let (_, rows) = fig7_eval_comparison(1, 2, Some(Fidelity::GnnTest), 5)
            .expect("CA simulation within budget");
        let r = &rows[0];
        assert!(r.gnn_ms.is_finite(), "pseudo-GNN timing column missing");
        assert!(r.gnn_err.is_finite());
    }
}
