//! Fig. 13 — the GPT-175B training design space: scatter of sampled
//! configurations (off-chip vs stacked DRAM), Pareto frontiers, and the
//! §IX-F comparisons against H100 / WSE2-like / Dojo-like baselines under
//! equal area.

use crate::arch::MemoryKind;
use crate::baselines;
use crate::coordinator::ref_power_for;
use crate::design_space;
use crate::eval::{eval_training, Analytical, SystemConfig};
use crate::explorer::{hypervolume, pareto_indices, Objective};
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::workload::models;

pub struct Fig13Point {
    pub stacking: bool,
    pub objective: Objective,
    pub summary: String,
}

pub struct Fig13Result {
    pub points: Vec<Fig13Point>,
    /// (name, objective) for each baseline.
    pub baselines: Vec<(String, Objective)>,
    /// Best WSC vs each baseline: (perf gain at <= power, power saving at >= perf).
    pub comparisons: Vec<(String, f64, f64)>,
}

pub fn fig13_design_space(bi: usize, samples: usize, seed: u64) -> (Table, Fig13Result) {
    let spec = models::benchmarks()[bi].clone();
    let mut rng = Rng::new(seed);
    let mut points = Vec::new();

    // Random scatter over the space (the blue/red dots of Fig. 13)...
    for _ in 0..samples {
        let Some(v) = design_space::sample_valid(&mut rng, 200) else {
            continue;
        };
        let sys = SystemConfig::area_matched(v.clone(), spec.gpu_num);
        let Some(r) = eval_training(&spec, &sys, &Analytical) else {
            continue;
        };
        points.push(Fig13Point {
            stacking: matches!(v.point.wsc.reticle.memory, MemoryKind::Stacking { .. }),
            objective: Objective {
                throughput: r.tokens_per_sec,
                power_w: r.power_w,
            },
            summary: v.point.wsc.summary(),
        });
    }
    // ...plus explorer-refined points (the paper's Pareto set comes from
    // the iterative search, not raw sampling).
    let obj = crate::eval::engine::Engine::analytical_training(spec.clone());
    let trace = crate::explorer::mobo(
        &obj,
        &crate::explorer::BoConfig {
            iters: samples / 2,
            init: 6,
            pool: 48,
            mc_samples: 32,
            ref_power: ref_power_for(&spec),
            seed,
            sample_tries: 3000,
        },
    );
    for p in &trace.points {
        points.push(Fig13Point {
            stacking: p.point.wsc.reticle.memory.is_stacking(),
            objective: p.objective,
            summary: p.point.wsc.summary(),
        });
    }

    // Baselines under the same area budget.
    let mut baseline_objs = Vec::new();
    if let Some(g) = baselines::h100_train_eval(&spec, spec.gpu_num) {
        baseline_objs.push((
            "H100 cluster".to_string(),
            Objective {
                throughput: g.tokens_per_sec,
                power_w: g.power_w,
            },
        ));
    }
    for (name, p) in [
        ("WSE2-like", baselines::wse2_like()),
        ("Dojo-like", baselines::dojo_like()),
    ] {
        let v = baselines::force_validate(&p);
        let sys = SystemConfig::area_matched(v, spec.gpu_num);
        if let Some(r) = eval_training(&spec, &sys, &Analytical) {
            baseline_objs.push((
                name.to_string(),
                Objective {
                    throughput: r.tokens_per_sec,
                    power_w: r.power_w,
                },
            ));
        }
    }

    // §IX-F-style comparisons: best searched WSC vs each baseline.
    let objs: Vec<Objective> = points.iter().map(|p| p.objective).collect();
    let front: Vec<Objective> = pareto_indices(&objs).into_iter().map(|i| objs[i]).collect();
    let mut comparisons = Vec::new();
    for (name, b) in &baseline_objs {
        // Perf gain at the same-or-lower power.
        let perf_gain = front
            .iter()
            .filter(|o| o.power_w <= b.power_w * 1.001)
            .map(|o| o.throughput / b.throughput - 1.0)
            .fold(f64::NEG_INFINITY, f64::max);
        // Power saving at the same-or-higher perf.
        let power_saving = front
            .iter()
            .filter(|o| o.throughput >= b.throughput * 0.999)
            .map(|o| 1.0 - o.power_w / b.power_w)
            .fold(f64::NEG_INFINITY, f64::max);
        comparisons.push((name.clone(), perf_gain, power_saving));
    }

    let ref_power = ref_power_for(&spec);
    let hv_stack = hypervolume(
        &points
            .iter()
            .filter(|p| p.stacking)
            .map(|p| p.objective)
            .collect::<Vec<_>>(),
        ref_power,
    );
    let hv_off = hypervolume(
        &points
            .iter()
            .filter(|p| !p.stacking)
            .map(|p| p.objective)
            .collect::<Vec<_>>(),
        ref_power,
    );

    let mut t = Table::new(
        &format!(
            "Fig. 13 — {} training design space ({} pts; HV stacking {:.3e} vs off-chip {:.3e})",
            spec.name,
            points.len(),
            hv_stack,
            hv_off
        ),
        &["entry", "tokens/s", "power(kW)", "note"],
    );
    for (name, b) in &baseline_objs {
        t.row(&[
            name.clone(),
            format!("{:.0}", b.throughput),
            format!("{:.0}", b.power_w / 1e3),
            "baseline".to_string(),
        ]);
    }
    let mut front_pts: Vec<&Fig13Point> = pareto_indices(&objs)
        .into_iter()
        .map(|i| &points[i])
        .collect();
    front_pts.sort_by(|a, b| b.objective.throughput.total_cmp(&a.objective.throughput));
    for p in front_pts.iter().take(8) {
        t.row(&[
            if p.stacking { "pareto(stack)" } else { "pareto(offchip)" }.to_string(),
            format!("{:.0}", p.objective.throughput),
            format!("{:.0}", p.objective.power_w / 1e3),
            p.summary.clone(),
        ]);
    }
    for (name, gain, saving) in &comparisons {
        t.row(&[
            format!("vs {name}"),
            format!("{:+.1}% perf", gain * 100.0),
            format!("{:+.1}% power", saving * 100.0),
            "pareto vs baseline".to_string(),
        ]);
    }

    (
        t,
        Fig13Result {
            points,
            baselines: baseline_objs,
            comparisons,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig13_smoke() {
        let (t, r) = fig13_design_space(0, 6, 17);
        assert!(!r.points.is_empty());
        assert!(!r.baselines.is_empty());
        assert!(t.render().contains("Fig. 13"));
    }
}
