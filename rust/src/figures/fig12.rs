//! Fig. 12 — inference speedup under heterogeneity levels (paper §IX-E):
//! core / reticle / wafer-granularity prefill-decode splits vs the
//! homogeneous design, across decode-stage stacking bandwidths. The paper's
//! takeaway 5: reticle-level heterogeneity gives the best tradeoff.

use crate::arch::{HeteroConfig, HeteroGranularity, MemoryKind};
use crate::design_space::{self, stack_capacity_gb};
use crate::eval::{eval_inference, Analytical, SystemConfig};
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::workload::models;

pub struct Fig12Row {
    pub granularity: HeteroGranularity,
    pub decode_bw: f64,
    pub prefill_ratio: f64,
    pub tokens_per_sec: f64,
    pub speedup_vs_homog: f64,
}

/// Errors instead of panicking when no base design can be sampled or the
/// homogeneous reference fails to evaluate — both mean the seed (or the
/// design space) changed out from under the figure, which callers should
/// report, not crash on.
pub fn fig12_hetero_speedup(seed: u64) -> Result<(Table, Vec<Fig12Row>), String> {
    let spec = models::benchmarks()[7].clone(); // GPT-175B
    let batch = 32;
    let mut rng = Rng::new(seed);

    // Base stacked-memory design for the decode stage comparison.
    let base = sample_stacked(&mut rng, 1.0).ok_or_else(|| {
        format!("fig12: no valid stacked-memory base design in 400 samples at seed {seed}")
    })?;
    let homog_sys = SystemConfig::area_matched(base.clone(), spec.gpu_num);
    let homog = eval_inference(&spec, &homog_sys, batch, false, &Analytical).ok_or_else(|| {
        format!(
            "fig12: homogeneous base design infeasible for {} inference at seed {seed}",
            spec.name
        )
    })?;

    let mut rows = Vec::new();
    for gran in [
        HeteroGranularity::None,
        HeteroGranularity::Core,
        HeteroGranularity::Reticle,
        HeteroGranularity::Wafer,
    ] {
        for &decode_bw in &[1.0, 2.0, 4.0] {
            // Optimize the prefill ratio per configuration (§IX-E: "By
            // adjusting the resource allocation between the two stages, we
            // can achieve the optimal overall throughput").
            let mut best: Option<Fig12Row> = None;
            for &ratio in &[0.3, 0.4, 0.5, 0.6, 0.7] {
                let mut point = base.point;
                point.hetero = HeteroConfig {
                    granularity: gran,
                    prefill_ratio: ratio,
                    decode_stack_bw: decode_bw,
                };
                let Ok(v) = design_space::validate(&point) else {
                    continue;
                };
                let sys = SystemConfig::area_matched(v, spec.gpu_num);
                let Some(r) = eval_inference(&spec, &sys, batch, false, &Analytical) else {
                    continue;
                };
                let row = Fig12Row {
                    granularity: gran,
                    decode_bw,
                    prefill_ratio: ratio,
                    tokens_per_sec: r.tokens_per_sec,
                    speedup_vs_homog: r.tokens_per_sec / homog.tokens_per_sec,
                };
                if best
                    .as_ref()
                    .map(|b| row.tokens_per_sec > b.tokens_per_sec)
                    .unwrap_or(true)
                {
                    best = Some(row);
                }
                if gran == HeteroGranularity::None {
                    break; // ratio is meaningless when homogeneous
                }
            }
            if let Some(b) = best {
                rows.push(b);
            }
        }
    }

    let mut t = Table::new(
        "Fig. 12 — GPT-175B inference speedup with heterogeneity",
        &["granularity", "decode bw", "best prefill ratio", "tokens/s", "speedup vs homog"],
    );
    for r in &rows {
        t.row(&[
            r.granularity.name().to_string(),
            format!("{}", r.decode_bw),
            format!("{:.1}", r.prefill_ratio),
            format!("{:.0}", r.tokens_per_sec),
            format!("{:.2}x", r.speedup_vs_homog),
        ]);
    }
    Ok((t, rows))
}

fn sample_stacked(rng: &mut Rng, bw: f64) -> Option<crate::design_space::Validated> {
    for _ in 0..400 {
        let mut p = design_space::sample_raw(rng);
        p.wsc.reticle.memory = MemoryKind::Stacking {
            bw_tbps_per_100mm2: bw,
            capacity_gb: stack_capacity_gb(bw),
        };
        if let Ok(v) = design_space::validate(&p) {
            return Some(v);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig12_smoke() {
        let (t, rows) = fig12_hetero_speedup(21).expect("fig12 generates at seed 21");
        assert!(!rows.is_empty());
        assert!(t.render().contains("Fig. 12"));
        // All four granularities represented.
        for g in HeteroGranularity::ALL {
            assert!(
                rows.iter().any(|r| r.granularity == g),
                "missing {}",
                g.name()
            );
        }
    }
}
