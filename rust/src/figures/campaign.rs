//! Campaign summary emitter: the cross-scenario table a `theseus
//! campaign` run prints — per-scenario status (`ok` / `resumed` /
//! `error`), final hypervolume, best Pareto point, and the
//! throughput/power comparison against the GPU-cluster reference, in the
//! spirit of the paper's Fig. 11–13 cross-workload comparisons. Rendered
//! from [`summarize_row`], the same digest `campaign.json` serializes, so
//! table and artifact cannot drift.

use crate::coordinator::campaign::{summarize_row, CampaignResult};
use crate::util::table::Table;

/// Render a [`CampaignResult`] as a fixed-width table, one row per
/// scenario (error rows show the isolating failure instead of metrics).
pub fn campaign_summary(result: &CampaignResult) -> Table {
    let mut t = Table::new(
        &format!(
            "Campaign summary — {} scenarios, seed {} ({} error rows, {} resumed)",
            result.rows.len(),
            result.campaign_seed,
            result.n_errors(),
            result.n_resumed()
        ),
        &[
            "scenario",
            "status",
            "points",
            "final HV",
            "best tok/s",
            "power(kW)",
            "vs GPU",
            "retained",
            "scaling",
            "ttft p99",
            "goodput",
        ],
    );
    let dash = || "-".to_string();
    for r in &result.rows {
        let s = summarize_row(r);
        let status = s.status().to_string();
        match s.error {
            None => {
                t.row(&[
                    s.key,
                    status,
                    s.points.to_string(),
                    format!("{:.3e}", s.final_hv),
                    s.best_throughput.map_or_else(dash, |x| format!("{x:.1}")),
                    s.best_power_w.map_or_else(dash, |x| format!("{:.1}", x / 1e3)),
                    s.speedup_vs_gpu.map_or_else(dash, |x| format!("{x:.2}x")),
                    // Fault-injection rows: throughput fraction retained
                    // on the defective wafer vs the same design pristine.
                    s.retained_fraction
                        .map_or_else(dash, |x| format!("{:.1}%", 100.0 * x)),
                    // Fixed-wafer rows: fraction of linear scaling the
                    // extra wafers retain vs the same design on one wafer.
                    s.scaling_efficiency
                        .map_or_else(dash, |x| format!("{:.1}%", 100.0 * x)),
                    // Serving rows: tail time-to-first-token and goodput
                    // under the scenario's SLO.
                    s.serving_ttft_p99
                        .map_or_else(dash, |x| format!("{:.0}ms", 1e3 * x)),
                    s.serving_goodput
                        .map_or_else(dash, |x| format!("{x:.2}/s")),
                ]);
            }
            Some(e) => {
                t.row(&[
                    s.key,
                    status,
                    dash(),
                    dash(),
                    dash(),
                    dash(),
                    dash(),
                    dash(),
                    dash(),
                    dash(),
                    e,
                ]);
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::campaign::{
        run_campaign, Budget, CampaignConfig, Fidelity, Scenario,
    };
    use crate::coordinator::Explorer;
    use crate::workload::Phase;

    #[test]
    fn campaign_summary_smoke_tiny() {
        let budget = Budget {
            iters: 1,
            init: 1,
            pool: 8,
            mc: 8,
            n1: 0,
            k: 0,
        };
        let cfg = CampaignConfig {
            scenarios: vec![
                Scenario {
                    model: "1.7".to_string(),
                    phase: Phase::Decode,
                    batch: 8,
                    mqa: false,
                    wafers: None,
                    explorer: Explorer::Random,
                    fidelity: Fidelity::Analytical,
                    budget,
                    fault_defect: None,
                    fault_spares: None,
                    hetero: None,
                    interwafer: None,
                    serving: None,
                    tag: String::new(),
                },
                Scenario {
                    model: "no-such-model".to_string(),
                    phase: Phase::Training,
                    batch: 0,
                    mqa: false,
                    wafers: None,
                    explorer: Explorer::Random,
                    fidelity: Fidelity::Analytical,
                    budget,
                    fault_defect: None,
                    fault_spares: None,
                    hetero: None,
                    interwafer: None,
                    serving: None,
                    tag: String::new(),
                },
            ],
            seed: 5,
            jobs: 1,
            resume_from: None,
            shard: None,
        };
        let result = run_campaign(&cfg).unwrap();
        let rendered = campaign_summary(&result).render();
        assert!(rendered.contains("Campaign summary"), "{rendered}");
        assert!(rendered.contains("1 error rows"), "{rendered}");
        assert!(rendered.contains("0 resumed"), "{rendered}");
        assert!(rendered.contains("unknown model"), "{rendered}");
    }
}
