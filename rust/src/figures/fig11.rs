//! Fig. 11 — LLM inference speedup over the H100 baseline at equal area
//! (paper §IX-D): (a) GPT-1.7B with all data SRAM-resident, swept over
//! on-chip SRAM bandwidth, ±MQA; (b) GPT-175B with stacked DRAM swept over
//! 0.25–4 TB/s/100 mm², ±MQA, with the prefill/decode latency breakdown.

use crate::arch::MemoryKind;
use crate::baselines::h100_infer_eval;
use crate::design_space::{self, stack_capacity_gb, DesignPoint};
use crate::eval::{eval_inference, Analytical, SystemConfig};
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::workload::models;

pub struct Fig11Row {
    pub sweep_value: f64,
    pub mqa: bool,
    pub wsc_tokens_per_sec: f64,
    pub gpu_tokens_per_sec: f64,
    pub speedup: f64,
    pub prefill_frac: f64,
    pub residency: &'static str,
}

/// Part (a): SRAM-bandwidth sweep on GPT-1.7B; part (b): stacking-DRAM
/// bandwidth sweep on GPT-175B. `part_b=false` selects (a).
pub fn fig11_inference_speedup(part_b: bool, seed: u64) -> (Table, Vec<Fig11Row>) {
    let spec = if part_b {
        models::benchmarks()[7].clone() // GPT-175B
    } else {
        models::benchmarks()[0].clone() // GPT-1.7B
    };
    let batch = 32;
    let gpus = equal_area_gpus(&spec);
    let mut rows = Vec::new();

    let sweep: Vec<f64> = if part_b {
        vec![0.25, 0.5, 1.0, 2.0, 4.0]
    } else {
        vec![128.0, 256.0, 512.0, 1024.0, 2048.0, 4096.0] // buffer bw bits
    };

    for &val in &sweep {
        for mqa in [true, false] {
            // Re-seed per sweep value: the base configuration is the same
            // draw every time, so rows differ ONLY in the swept parameter.
            let mut rng = Rng::new(seed);
            let Some(v) = sample_cfg(&mut rng, part_b, val) else {
                continue;
            };
            let sys = SystemConfig::area_matched(v.clone(), spec.gpu_num);
            let Some(w) = eval_inference(&spec, &sys, batch, mqa, &Analytical) else {
                continue;
            };
            let g = h100_infer_eval(&spec, gpus, batch, mqa);
            let gpu_tps = g.as_ref().map(|g| g.tokens_per_sec).unwrap_or(f64::NAN);
            let decode_total = w.decode_step_s * spec.seq_len as f64;
            rows.push(Fig11Row {
                sweep_value: val,
                mqa,
                wsc_tokens_per_sec: w.tokens_per_sec,
                gpu_tokens_per_sec: gpu_tps,
                speedup: w.tokens_per_sec / gpu_tps,
                prefill_frac: w.prefill_s / (w.prefill_s + decode_total),
                residency: w.residency,
            });
        }
    }

    let title = if part_b {
        format!(
            "Fig. 11(b) — {} inference vs H100 (stacking DRAM bw sweep, TB/s/100mm2)",
            spec.name
        )
    } else {
        format!(
            "Fig. 11(a) — {} inference vs H100 (SRAM bandwidth sweep, bit/cycle/core)",
            spec.name
        )
    };
    let mut t = Table::new(
        &title,
        &["sweep", "mqa", "wsc tok/s", "h100 tok/s", "speedup", "prefill frac", "residency"],
    );
    for r in &rows {
        t.row(&[
            format!("{}", r.sweep_value),
            r.mqa.to_string(),
            format!("{:.0}", r.wsc_tokens_per_sec),
            format!("{:.0}", r.gpu_tokens_per_sec),
            format!("{:.2}x", r.speedup),
            format!("{:.2}", r.prefill_frac),
            r.residency.to_string(),
        ]);
    }
    (t, rows)
}

/// GPU count with the same total die area as the area-matched WSC system
/// (§VIII-A: "total area of the WSCs consistent with that of the
/// corresponding number of GPUs" — we give both sides spec.gpu_num dies'
/// worth of area, but inference at batch 32 uses the minimum GPUs that fit
/// the model, as the paper's per-request comparison does).
fn equal_area_gpus(spec: &crate::workload::LlmSpec) -> usize {
    let need = spec.param_bytes() + spec.kv_cache_bytes_per_seq(false) * 32.0;
    let min_fit = (need / 80e9).ceil() as usize;
    min_fit.max(8).min(spec.gpu_num)
}

fn sample_cfg(
    rng: &mut Rng,
    part_b: bool,
    val: f64,
) -> Option<crate::design_space::Validated> {
    for _ in 0..400 {
        let mut p: DesignPoint = design_space::sample_raw(rng);
        if part_b {
            p.wsc.reticle.memory = MemoryKind::Stacking {
                bw_tbps_per_100mm2: val,
                capacity_gb: stack_capacity_gb(val),
            };
        } else {
            // SRAM-resident study (paper: "all necessary data ... stored in
            // the SRAM of WSCs"): max out per-core SRAM and the array so
            // weights + KV fit on-wafer, sweep only the SRAM bandwidth.
            p.wsc.reticle.core.buffer_bw_bits = val as usize;
            p.wsc.reticle.core.buffer_kb = 2048;
            // Small MACs, big SRAM, many cores — a WSE-class sea of memory
            // that keeps weights + KV resident and under the power cap.
            p.wsc.reticle.core.mac_num = 128;
            p.wsc.reticle.core.noc_bw_bits = p.wsc.reticle.core.noc_bw_bits.min(512);
            p.wsc.reticle.array_h = 12;
            p.wsc.reticle.array_w = 12;
            p.wsc.reticle_h = p.wsc.reticle_h.max(8);
            p.wsc.reticle_w = p.wsc.reticle_w.max(8);
            p.wsc.reticle.inter_reticle_bw_ratio = p.wsc.reticle.inter_reticle_bw_ratio.min(1.0);
            p.wsc.reticle.memory = MemoryKind::OffChip;
        }
        if let Ok(v) = design_space::validate(&p) {
            return Some(v);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig11a_smoke() {
        let (t, rows) = fig11_inference_speedup(false, 9);
        assert!(!rows.is_empty());
        assert!(t.render().contains("Fig. 11(a)"));
        // MQA rows must beat their non-MQA siblings at equal sweep value
        // whenever decode dominates; at minimum speedups are positive.
        for r in &rows {
            assert!(r.speedup.is_finite() && r.speedup > 0.0);
        }
    }

    #[test]
    fn fig11b_smoke() {
        let (_, rows) = fig11_inference_speedup(true, 9);
        assert!(!rows.is_empty());
        // Higher stacking bandwidth should not hurt decode throughput:
        // compare min and max sweep at fixed mqa=false.
        let lo = rows
            .iter()
            .filter(|r| !r.mqa)
            .min_by(|a, b| a.sweep_value.partial_cmp(&b.sweep_value).unwrap());
        let hi = rows
            .iter()
            .filter(|r| !r.mqa)
            .max_by(|a, b| a.sweep_value.partial_cmp(&b.sweep_value).unwrap());
        if let (Some(lo), Some(hi)) = (lo, hi) {
            assert!(
                hi.wsc_tokens_per_sec >= lo.wsc_tokens_per_sec * 0.5,
                "hi bw collapsed: {} vs {}",
                hi.wsc_tokens_per_sec,
                lo.wsc_tokens_per_sec
            );
        }
    }
}
