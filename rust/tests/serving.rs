//! Serving-simulator integration suite: the closed-form single-request
//! sanity check (latency = prefill + N·decode exactly), scheduler
//! semantics on constructed traces, multi-wafer KV hand-off degradation
//! under a slow inter-wafer network, and the campaign-level contracts —
//! same-seed serving campaigns serialize byte-identical artifacts that
//! carry the serving digest, and a killed-then-resumed serving row equals
//! an uninterrupted one byte for byte.

use theseus::arch::{InterWaferNet, InterWaferTopology};
use theseus::coordinator::campaign::{
    run_campaign, scenario_result_json, summary_json, write_artifacts, Budget, CampaignConfig,
    Fidelity, Scenario,
};
use theseus::coordinator::Explorer;
use theseus::design_space::{reference_point, validate};
use theseus::eval::engine::{Engine, EvalSpec};
use theseus::eval::SystemConfig;
use theseus::serving::{simulate, ArrivalProcess, Request, SchedulerKind, ServingSpec};
use theseus::util::json::Json;
use theseus::workload::{models, Phase};

fn reference_system(n_wafers: usize) -> SystemConfig {
    let v = validate(&reference_point()).expect("reference point valid");
    SystemConfig {
        validated: v,
        n_wafers,
        faults: None,
    }
}

fn decode_engine(batch: usize) -> Engine {
    let model = models::find_or_usage("1.7").unwrap();
    Engine::new(EvalSpec::inference(model, Phase::Decode, batch)).unwrap()
}

#[test]
fn single_request_latency_is_prefill_plus_decodes() {
    // The closed-form contract the simulator's docs pin: one request, no
    // queueing, no contention — its latency is exactly prefill_s(1) +
    // N·decode_step_s(1) from the Engine, and its TTFT is prefill plus
    // one decode step (prefill emits no token).
    let engine = decode_engine(8);
    let sys = reference_system(1);
    let costs = engine
        .eval_infer_system_at_batch(&sys, 1)
        .expect("reference design serves batch 1");
    let n_out = 16usize;
    let trace = vec![Request {
        id: 0,
        arrival_s: 0.0,
        prompt_tokens: 256,
        output_tokens: n_out,
    }];
    for scheduler in SchedulerKind::ALL {
        let outcomes = simulate(&engine, &sys, &trace, scheduler).unwrap();
        assert_eq!(outcomes.len(), 1);
        let o = &outcomes[0];
        let expected_latency = costs.prefill_s + n_out as f64 * costs.decode_step_s;
        let expected_ttft = costs.prefill_s + costs.decode_step_s;
        assert!(
            (o.latency_s() - expected_latency).abs() < 1e-9,
            "{}: latency {} vs closed form {expected_latency}",
            scheduler.name(),
            o.latency_s()
        );
        assert!(
            (o.ttft_s() - expected_ttft).abs() < 1e-9,
            "{}: ttft {} vs closed form {expected_ttft}",
            scheduler.name(),
            o.ttft_s()
        );
    }
}

#[test]
fn prefill_priority_gets_a_late_arrival_to_first_token_sooner() {
    // One long request decoding when a second arrives. FCFS fuses the
    // late prefill with an in-flight decode round (the prefill ends after
    // prefill + decode time); prefill-priority runs a prefill-only round
    // (prefill time alone), so the late request reaches its first token
    // strictly sooner — the scheduler trade-off the module docs state.
    let engine = decode_engine(8);
    let sys = reference_system(1);
    let trace = vec![
        Request {
            id: 0,
            arrival_s: 0.0,
            prompt_tokens: 256,
            output_tokens: 64,
        },
        // Arrives mid-prefill of request 0 (any arrival in (0, prefill)
        // lands in the same schedule).
        Request {
            id: 1,
            arrival_s: 1e-9,
            prompt_tokens: 256,
            output_tokens: 4,
        },
    ];
    let fcfs = simulate(&engine, &sys, &trace, SchedulerKind::Fcfs).unwrap();
    let pp = simulate(&engine, &sys, &trace, SchedulerKind::PrefillPriority).unwrap();
    assert!(
        pp[1].ttft_s() < fcfs[1].ttft_s(),
        "prefill-priority ttft {} must beat fcfs ttft {}",
        pp[1].ttft_s(),
        fcfs[1].ttft_s()
    );
    // Determinism: re-simulation is byte-identical.
    assert_eq!(fcfs, simulate(&engine, &sys, &trace, SchedulerKind::Fcfs).unwrap());
    assert_eq!(
        pp,
        simulate(&engine, &sys, &trace, SchedulerKind::PrefillPriority).unwrap()
    );
}

#[test]
fn slow_interwafer_handoff_degrades_saturating_load_vs_one_wafer() {
    // The same per-wafer design serving the same saturating trace: on 4
    // wafers with a crippled inter-wafer network, the cross-wafer KV
    // hand-offs (3/4 of requests under round-robin placement) dominate
    // TTFT — the serving digest must show measurable degradation vs the
    // single wafer, where the net is never consulted.
    let mut p = reference_point();
    p.interwafer = InterWaferNet {
        topology: InterWaferTopology::Ring,
        links_per_wafer: 2,
        link_bandwidth: 1e6, // ~seconds per multi-MB KV hand-off
        link_latency: 0.5,
    };
    let v = validate(&p).expect("reference point with slow interwafer still validates");
    let sys1 = SystemConfig {
        validated: v.clone(),
        n_wafers: 1,
        faults: None,
    };
    let sys4 = SystemConfig {
        validated: v,
        n_wafers: 4,
        faults: None,
    };
    let engine = decode_engine(16);
    let trace = theseus::serving::trace::generate(ArrivalProcess::Poisson, 64.0, 32, 256, 8, 5);
    let m1 = theseus::serving::evaluate(&engine, &sys1, &trace, SchedulerKind::Fcfs, 0.5).unwrap();
    let m4 = theseus::serving::evaluate(&engine, &sys4, &trace, SchedulerKind::Fcfs, 0.5).unwrap();
    assert_eq!(m1.completed, 32);
    assert_eq!(m4.completed, 32);
    assert!(
        m4.ttft_p99_s > 2.0 * m1.ttft_p99_s,
        "4-wafer ttft p99 {} must degrade vs 1-wafer {}",
        m4.ttft_p99_s,
        m1.ttft_p99_s
    );
    assert!(
        m4.tokens_per_sec < m1.tokens_per_sec,
        "4-wafer tok/s {} must degrade vs 1-wafer {}",
        m4.tokens_per_sec,
        m1.tokens_per_sec
    );
}

fn serving_scenario(wafers: Option<usize>, rate: f64) -> Scenario {
    Scenario {
        model: "GPT-1.7B".to_string(),
        phase: Phase::Decode,
        batch: 8,
        mqa: false,
        wafers,
        explorer: Explorer::Random,
        fidelity: Fidelity::Analytical,
        budget: Budget {
            iters: 1,
            init: 2,
            pool: 8,
            mc: 8,
            n1: 0,
            k: 0,
        },
        fault_defect: None,
        fault_spares: None,
        hetero: None,
        interwafer: None,
        serving: Some(ServingSpec {
            arrival: ArrivalProcess::Poisson,
            rate_per_s: rate,
            requests: 12,
            mean_prompt: 128,
            mean_output: 8,
            slo_s: 0.5,
            scheduler: SchedulerKind::Fcfs,
        }),
        tag: String::new(),
    }
}

fn fresh_cfg(scenarios: Vec<Scenario>, seed: u64, jobs: usize) -> CampaignConfig {
    CampaignConfig {
        scenarios,
        seed,
        jobs,
        resume_from: None,
        shard: None,
    }
}

#[test]
fn same_seed_serving_campaigns_are_byte_identical_and_carry_the_digest() {
    // A serving row on a 2-wafer system rides the campaign end to end:
    // artifact carries the full serving digest, the summary carries the
    // serving columns, and two same-seed runs serialize byte-identically
    // (the digest is computed from the scenario-derived trace, not from
    // any ambient state).
    let cfg = fresh_cfg(vec![serving_scenario(Some(2), 16.0)], 41, 1);
    let r1 = run_campaign(&cfg).unwrap();
    let r2 = run_campaign(&cfg).unwrap();
    assert_eq!(r1.n_errors(), 0, "{:?}", r1.rows[0].outcome.error());

    let doc = scenario_result_json(&r1.rows[0]);
    let sv = doc.get("serving").expect("serving row must carry its digest");
    for key in [
        "completed",
        "goodput_per_sec",
        "latency_p50_s",
        "latency_p99_s",
        "makespan_s",
        "slo_s",
        "tokens_per_sec",
        "ttft_p50_s",
        "ttft_p99_s",
    ] {
        assert!(
            sv.get(key).and_then(Json::as_f64).is_some(),
            "serving digest missing {key}"
        );
    }
    assert_eq!(sv.get("completed").and_then(Json::as_f64), Some(12.0));
    // 2-wafer serving rows also digest scaling (the axes compose).
    assert!(doc.get("scaling").is_some());

    let summary = summary_json(&r1);
    let row = &summary.get("scenarios").unwrap().as_arr().unwrap()[0];
    for key in ["serving_goodput", "serving_tokens_per_sec", "serving_ttft_p99"] {
        assert!(
            row.get(key).and_then(Json::as_f64).is_some(),
            "summary row missing {key}"
        );
    }

    // Byte-identical across same-seed runs.
    assert_eq!(summary.to_pretty(), summary_json(&r2).to_pretty());
    assert_eq!(
        doc.to_pretty(),
        scenario_result_json(&r2.rows[0]).to_pretty()
    );
}

#[test]
fn non_serving_rows_never_grow_serving_fields() {
    // Pre-serving campaigns keep their exact bytes: no "serving" object
    // in the artifact, no serving_* keys in the summary row.
    let mut s = serving_scenario(None, 4.0);
    s.serving = None;
    let r = run_campaign(&fresh_cfg(vec![s], 7, 1)).unwrap();
    assert_eq!(r.n_errors(), 0);
    let doc = scenario_result_json(&r.rows[0]);
    assert!(doc.get("serving").is_none());
    let summary = summary_json(&r);
    let row = &summary.get("scenarios").unwrap().as_arr().unwrap()[0];
    for key in ["serving_goodput", "serving_tokens_per_sec", "serving_ttft_p99"] {
        assert!(row.get(key).is_none(), "non-serving row grew {key}");
    }
}

#[test]
fn killed_then_resumed_serving_campaign_is_byte_identical() {
    // The resume contract extends to serving rows: the digest is stored
    // in the artifact, so a resumed row re-serializes it byte-identically
    // without re-running the simulator.
    let seed = 53;
    let scenarios = vec![serving_scenario(None, 4.0), serving_scenario(None, 16.0)];
    let cfg = fresh_cfg(scenarios.clone(), seed, 1);

    let full = run_campaign(&cfg).unwrap();
    assert_eq!(full.n_errors(), 0);
    let dir_full = std::env::temp_dir().join(format!(
        "theseus-serving-uninterrupted-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir_full);
    write_artifacts(&full, &dir_full).unwrap();

    // "Killed" after the first scenario; resume the full matrix.
    let partial = run_campaign(&fresh_cfg(vec![scenarios[0].clone()], seed, 1)).unwrap();
    let dir_resumed = std::env::temp_dir().join(format!(
        "theseus-serving-resumed-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir_resumed);
    write_artifacts(&partial, &dir_resumed).unwrap();
    let resumed = run_campaign(&CampaignConfig {
        scenarios: scenarios.clone(),
        seed,
        jobs: 1,
        resume_from: Some(dir_resumed.clone()),
        shard: None,
    })
    .unwrap();
    assert!(resumed.rows[0].outcome.is_resumed());
    assert_eq!(resumed.n_resumed(), 1);
    write_artifacts(&resumed, &dir_resumed).unwrap();

    for s in &scenarios {
        let name = format!("{}.json", s.key());
        let a = std::fs::read_to_string(dir_full.join("scenarios").join(&name)).unwrap();
        let b = std::fs::read_to_string(dir_resumed.join("scenarios").join(&name)).unwrap();
        assert_eq!(a, b, "serving artifact {name} diverged after resume");
        // Both carry the digest.
        assert!(Json::parse(&a).unwrap().get("serving").is_some());
    }
    // campaign.json identical modulo the resumed marker — serving summary
    // columns included.
    let a = std::fs::read_to_string(dir_full.join("campaign.json")).unwrap();
    let b = std::fs::read_to_string(dir_resumed.join("campaign.json")).unwrap();
    assert!(a.contains("serving_ttft_p99"), "{a}");
    assert_eq!(a, b.replace("\"status\": \"resumed\"", "\"status\": \"ok\""));

    let _ = std::fs::remove_dir_all(&dir_full);
    let _ = std::fs::remove_dir_all(&dir_resumed);
}
