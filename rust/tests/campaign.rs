//! Campaign-engine integration suite: the determinism contract (same
//! campaign seed ⇒ byte-identical serialized artifacts), scenario-failure
//! isolation, the golden-pinned `paper_suite()` JSON schema, and the
//! exit-1-with-usage CLI contract for unknown `--model` / `--explorer` /
//! `--suite` keys. `THESEUS_TEST_FAST=1` shrinks the test campaign
//! (fewer scenarios, 1-iteration budgets) so tier-1 stays fast.

use std::process::Command;

use theseus::coordinator::campaign::{
    paper_suite, run_campaign, scenario_result_json, scenarios_from_json, suite_to_json,
    summary_json, write_artifacts, Budget, CampaignConfig, Fidelity, Scenario, ScenarioPhase,
};
use theseus::coordinator::Explorer;
use theseus::util::cli::env_flag;
use theseus::util::json::Json;

fn scenario(
    phase: ScenarioPhase,
    batch: usize,
    wafers: Option<usize>,
    explorer: Explorer,
    fidelity: Fidelity,
    budget: Budget,
) -> Scenario {
    Scenario {
        model: "GPT-1.7B".to_string(),
        phase,
        batch,
        wafers,
        explorer,
        fidelity,
        budget,
        tag: String::new(),
    }
}

/// A miniature slice of the paper matrix — FAST-shrunk under
/// `THESEUS_TEST_FAST=1` (the bench_check.sh default) so the determinism
/// contract stays cheap enough for tier-1.
fn test_campaign(seed: u64) -> CampaignConfig {
    let fast = env_flag("THESEUS_TEST_FAST");
    let b = Budget {
        iters: if fast { 1 } else { 2 },
        init: if fast { 1 } else { 2 },
        pool: 8,
        mc: 8,
        n1: 1,
        k: 1,
    };
    let mut scenarios = vec![
        scenario(
            ScenarioPhase::Training,
            0,
            None,
            Explorer::Random,
            Fidelity::Analytical,
            b,
        ),
        scenario(
            ScenarioPhase::Decode,
            8,
            None,
            Explorer::Mobo,
            Fidelity::Analytical,
            b,
        ),
    ];
    if !fast {
        // A third scenario crossing explorer (MFMOBO's fidelity handoff)
        // and a pinned wafer count.
        scenarios.push(scenario(
            ScenarioPhase::Training,
            0,
            Some(1),
            Explorer::Mfmobo,
            Fidelity::Analytical,
            b,
        ));
    }
    CampaignConfig {
        scenarios,
        seed,
        jobs: 2,
    }
}

#[test]
fn same_seed_campaigns_are_byte_identical() {
    let cfg = test_campaign(2024);
    let r1 = run_campaign(&cfg).unwrap();
    let r2 = run_campaign(&cfg).unwrap();

    // Every scenario produced a real trace with a Pareto front and a
    // hypervolume (no silent empty results).
    for r in &r1.rows {
        let trace = r
            .outcome
            .as_ref()
            .unwrap_or_else(|e| panic!("scenario {} failed: {e}", r.scenario.key()));
        assert!(!trace.points.is_empty(), "{}", r.scenario.key());
        let doc = scenario_result_json(r);
        assert!(doc.get("pareto").unwrap().as_arr().unwrap().len() >= 1);
        assert!(doc.get("final_hv").unwrap().as_f64().unwrap() > 0.0);
        assert!(doc.get("trace").unwrap().get("points").is_some());
    }

    // The determinism contract: both runs serialize byte-identically.
    assert_eq!(
        summary_json(&r1).to_pretty(),
        summary_json(&r2).to_pretty()
    );
    for (a, b) in r1.rows.iter().zip(&r2.rows) {
        assert_eq!(
            scenario_result_json(a).to_pretty(),
            scenario_result_json(b).to_pretty(),
            "scenario {} diverged between same-seed runs",
            a.scenario.key()
        );
    }

    // And the artifacts dir holds exactly those bytes.
    let dir = std::env::temp_dir().join(format!(
        "theseus-campaign-determinism-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    write_artifacts(&r1, &dir).unwrap();
    let on_disk = std::fs::read_to_string(dir.join("campaign.json")).unwrap();
    assert_eq!(on_disk, summary_json(&r2).to_pretty() + "\n");
    for r in &r2.rows {
        let path = dir
            .join("scenarios")
            .join(format!("{}.json", r.scenario.key()));
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("missing artifact {}: {e}", path.display()));
        assert_eq!(text, scenario_result_json(r).to_pretty() + "\n");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn poisoned_scenarios_do_not_sink_the_campaign() {
    let b = Budget {
        iters: 1,
        init: 1,
        pool: 8,
        mc: 8,
        n1: 0,
        k: 0,
    };
    let mut poisoned = scenario(
        ScenarioPhase::Training,
        0,
        None,
        Explorer::Random,
        Fidelity::Analytical,
        b,
    );
    poisoned.model = "no-such-model".to_string();
    let cfg = CampaignConfig {
        scenarios: vec![
            scenario(
                ScenarioPhase::Decode,
                4,
                None,
                Explorer::Random,
                Fidelity::Analytical,
                b,
            ),
            poisoned,
            // Unsupported fidelity for inference: a second failure mode.
            scenario(
                ScenarioPhase::Decode,
                4,
                None,
                Explorer::Random,
                Fidelity::CycleAccurate,
                b,
            ),
        ],
        seed: 7,
        jobs: 2,
    };
    let result = run_campaign(&cfg).unwrap();
    assert_eq!(result.rows.len(), 3);
    assert_eq!(result.n_errors(), 2);
    assert!(result.rows[0].outcome.is_ok(), "healthy scenario sunk");
    let e = result.rows[1].outcome.as_ref().unwrap_err();
    assert!(e.contains("unknown model 'no-such-model'"), "{e}");
    let e = result.rows[2].outcome.as_ref().unwrap_err();
    assert!(e.contains("analytical"), "{e}");

    // The summary records per-row status instead of aborting.
    let sj = summary_json(&result);
    assert_eq!(sj.get("n_errors").unwrap().as_f64(), Some(2.0));
    let rows = sj.get("scenarios").unwrap().as_arr().unwrap();
    assert_eq!(rows[0].get("status").unwrap().as_str(), Some("ok"));
    assert_eq!(rows[1].get("status").unwrap().as_str(), Some("error"));
    assert!(rows[1].get("error").unwrap().as_str().is_some());
}

#[test]
fn paper_suite_schema_is_golden_pinned() {
    let golden_path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/golden/campaign_suite.json"
    );
    let golden = std::fs::read_to_string(golden_path).unwrap();
    let emitted = suite_to_json(&paper_suite()).to_pretty() + "\n";
    assert_eq!(
        emitted, golden,
        "paper_suite() JSON schema drifted from tests/golden/campaign_suite.json — \
         if the change is intentional, regenerate the golden file so the drift is a reviewed diff"
    );
    // decode → encode round-trips byte-identically...
    let parsed = Json::parse(&golden).unwrap();
    assert_eq!(parsed.to_pretty() + "\n", golden);
    // ...including through the typed Scenario layer.
    let scenarios = scenarios_from_json(&parsed).unwrap();
    assert_eq!(scenarios, paper_suite());
    assert_eq!(suite_to_json(&scenarios).to_pretty() + "\n", golden);
}

#[test]
fn cli_unknown_keys_exit_1_listing_options() {
    let bin = env!("CARGO_BIN_EXE_theseus");

    let out = Command::new(bin)
        .args(["dse", "--model", "gpt-nonexistent"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown model 'gpt-nonexistent'"), "{err}");
    assert!(err.contains("GPT-175B"), "must list valid models: {err}");

    let out = Command::new(bin)
        .args(["dse", "--model", "1.7", "--explorer", "bogus"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown explorer 'bogus'"), "{err}");
    assert!(err.contains("random, mobo, mfmobo"), "{err}");

    let out = Command::new(bin)
        .args(["eval", "--model", "nope"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown model 'nope'"));

    let out = Command::new(bin)
        .args(["campaign", "--suite", "imaginary"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown suite 'imaginary'"));
}

#[test]
fn cli_campaign_scenarios_file_end_to_end() {
    let bin = env!("CARGO_BIN_EXE_theseus");
    let dir = std::env::temp_dir().join(format!("theseus-campaign-cli-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let scen_file = dir.join("scenarios.json");
    std::fs::write(
        &scen_file,
        r#"{"scenarios": [{"model": "GPT-1.7B", "phase": "decode", "explorer": "random",
            "batch": 4, "iters": 1, "init": 1, "pool": 8, "mc": 8, "n1": 0, "k": 0}]}"#,
    )
    .unwrap();
    let out_dir = dir.join("out");
    let out = Command::new(bin)
        .args([
            "campaign",
            "--scenarios",
            scen_file.to_str().unwrap(),
            "--out",
            out_dir.to_str().unwrap(),
            "--seed",
            "3",
            "--jobs",
            "1",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("Campaign summary"));

    let summary =
        Json::parse(&std::fs::read_to_string(out_dir.join("campaign.json")).unwrap()).unwrap();
    assert_eq!(summary.get("n_errors").unwrap().as_f64(), Some(0.0));
    assert_eq!(summary.get("n_scenarios").unwrap().as_f64(), Some(1.0));
    let key = "gpt-1.7b-decode-random-analytical-b4-wauto";
    let scen_doc = Json::parse(
        &std::fs::read_to_string(out_dir.join("scenarios").join(format!("{key}.json"))).unwrap(),
    )
    .unwrap();
    assert_eq!(scen_doc.get("status").unwrap().as_str(), Some("ok"));
    assert!(scen_doc.get("trace").is_some());
    let _ = std::fs::remove_dir_all(&dir);
}
