//! Campaign-engine integration suite: the determinism contract (same
//! campaign seed ⇒ byte-identical serialized artifacts), scenario-failure
//! isolation, campaign-level resume (killed-then-resumed ≡ uninterrupted),
//! the golden-pinned `paper_suite()` JSON schema, and the
//! exit-1-with-usage CLI contract for unknown `--model` / `--explorer` /
//! `--suite` keys. `THESEUS_TEST_FAST=1` shrinks the test campaign
//! (fewer scenarios, 1-iteration budgets) so tier-1 stays fast.

use std::process::Command;

use theseus::coordinator::campaign::{
    merge_campaign, paper_suite, run_campaign, scenario_result_json, scenarios_from_json,
    serving_suite, suite_to_json, summary_json, wafer_sweep_suite, write_artifacts, Budget,
    CampaignConfig, Fidelity, Scenario,
};
use theseus::coordinator::Explorer;
use theseus::util::cli::env_flag;
use theseus::util::json::Json;
use theseus::workload::Phase;

fn scenario(
    phase: Phase,
    batch: usize,
    wafers: Option<usize>,
    explorer: Explorer,
    fidelity: Fidelity,
    budget: Budget,
) -> Scenario {
    Scenario {
        model: "GPT-1.7B".to_string(),
        phase,
        batch,
        mqa: false,
        wafers,
        explorer,
        fidelity,
        budget,
        fault_defect: None,
        fault_spares: None,
        hetero: None,
        interwafer: None,
        serving: None,
        tag: String::new(),
    }
}

fn fresh_cfg(scenarios: Vec<Scenario>, seed: u64, jobs: usize) -> CampaignConfig {
    CampaignConfig {
        scenarios,
        seed,
        jobs,
        resume_from: None,
        shard: None,
    }
}

/// A miniature slice of the paper matrix — FAST-shrunk under
/// `THESEUS_TEST_FAST=1` (the bench_check.sh default) so the determinism
/// contract stays cheap enough for tier-1.
fn test_campaign(seed: u64) -> CampaignConfig {
    let fast = env_flag("THESEUS_TEST_FAST");
    let b = Budget {
        iters: if fast { 1 } else { 2 },
        init: if fast { 1 } else { 2 },
        pool: 8,
        mc: 8,
        n1: 1,
        k: 1,
    };
    let mut scenarios = vec![
        scenario(Phase::Training, 0, None, Explorer::Random, Fidelity::Analytical, b),
        scenario(Phase::Decode, 8, None, Explorer::Mobo, Fidelity::Analytical, b),
    ];
    if !fast {
        // A third scenario crossing explorer (MFMOBO's fidelity handoff),
        // a pinned wafer count, and the batched pseudo-GNN fidelity.
        scenarios.push(scenario(
            Phase::Training,
            0,
            Some(1),
            Explorer::Mfmobo,
            Fidelity::GnnTest,
            b,
        ));
    }
    fresh_cfg(scenarios, seed, 2)
}

#[test]
fn same_seed_campaigns_are_byte_identical() {
    let cfg = test_campaign(2024);
    let r1 = run_campaign(&cfg).unwrap();
    let r2 = run_campaign(&cfg).unwrap();

    // Every scenario produced a real trace with a Pareto front and a
    // hypervolume (no silent empty results).
    for r in &r1.rows {
        if let Some(e) = r.outcome.error() {
            panic!("scenario {} failed: {e}", r.scenario.key());
        }
        let trace = r.outcome.trace().expect("fresh run has in-memory traces");
        assert!(!trace.points.is_empty(), "{}", r.scenario.key());
        let doc = scenario_result_json(r);
        assert!(doc.get("pareto").unwrap().as_arr().unwrap().len() >= 1);
        assert!(doc.get("final_hv").unwrap().as_f64().unwrap() > 0.0);
        assert!(doc.get("trace").unwrap().get("points").is_some());
    }

    // The determinism contract: both runs serialize byte-identically.
    assert_eq!(
        summary_json(&r1).to_pretty(),
        summary_json(&r2).to_pretty()
    );
    for (a, b) in r1.rows.iter().zip(&r2.rows) {
        assert_eq!(
            scenario_result_json(a).to_pretty(),
            scenario_result_json(b).to_pretty(),
            "scenario {} diverged between same-seed runs",
            a.scenario.key()
        );
    }

    // And the artifacts dir holds exactly those bytes.
    let dir = std::env::temp_dir().join(format!(
        "theseus-campaign-determinism-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    write_artifacts(&r1, &dir).unwrap();
    let on_disk = std::fs::read_to_string(dir.join("campaign.json")).unwrap();
    assert_eq!(on_disk, summary_json(&r2).to_pretty() + "\n");
    for r in &r2.rows {
        let path = dir
            .join("scenarios")
            .join(format!("{}.json", r.scenario.key()));
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("missing artifact {}: {e}", path.display()));
        assert_eq!(text, scenario_result_json(r).to_pretty() + "\n");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn killed_then_resumed_campaign_is_byte_identical() {
    // The --resume contract: a campaign killed after some scenarios wrote
    // their artifacts and then re-run with resume_from must produce
    // byte-identical scenario artifacts to an uninterrupted run, and the
    // already-done scenarios must not be re-evaluated (their rows come
    // from disk, marked `resumed` — the status marker in campaign.json is
    // the only difference).
    let seed = 77;
    let cfg = test_campaign(seed);

    // Uninterrupted reference run.
    let full = run_campaign(&cfg).unwrap();
    let dir_full = std::env::temp_dir().join(format!(
        "theseus-campaign-uninterrupted-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir_full);
    write_artifacts(&full, &dir_full).unwrap();

    // "Killed" run: only the first scenario finished. Per-scenario seeds
    // are position-independent, so running it alone writes the exact
    // bytes the full campaign would.
    let partial = run_campaign(&fresh_cfg(vec![cfg.scenarios[0].clone()], seed, 1)).unwrap();
    let dir_resumed = std::env::temp_dir().join(format!(
        "theseus-campaign-resumed-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir_resumed);
    write_artifacts(&partial, &dir_resumed).unwrap();

    // Resume the full matrix against the partial artifact dir.
    let resumed = run_campaign(&CampaignConfig {
        scenarios: cfg.scenarios.clone(),
        seed,
        jobs: 2,
        resume_from: Some(dir_resumed.clone()),
        shard: None,
    })
    .unwrap();
    assert!(resumed.rows[0].outcome.is_resumed(), "existing artifact must be skipped");
    assert!(resumed.rows[0].outcome.error().is_none());
    for r in &resumed.rows[1..] {
        assert!(!r.outcome.is_resumed(), "missing artifacts must run fresh");
    }
    assert_eq!(resumed.n_resumed(), 1);
    write_artifacts(&resumed, &dir_resumed).unwrap();

    // Every scenario artifact byte-identical to the uninterrupted run.
    for r in &full.rows {
        let name = format!("{}.json", r.scenario.key());
        let a = std::fs::read_to_string(dir_full.join("scenarios").join(&name)).unwrap();
        let b = std::fs::read_to_string(dir_resumed.join("scenarios").join(&name)).unwrap();
        assert_eq!(a, b, "scenario artifact {name} diverged after resume");
    }
    // campaign.json identical modulo the resumed marker.
    let a = std::fs::read_to_string(dir_full.join("campaign.json")).unwrap();
    let b = std::fs::read_to_string(dir_resumed.join("campaign.json")).unwrap();
    assert!(b.contains("\"status\": \"resumed\""), "{b}");
    assert_eq!(a, b.replace("\"status\": \"resumed\"", "\"status\": \"ok\""));

    let _ = std::fs::remove_dir_all(&dir_full);
    let _ = std::fs::remove_dir_all(&dir_resumed);
}

#[test]
fn resume_refuses_wrong_seed_artifacts() {
    // An artifact recorded under a different campaign seed must become a
    // loud error row — neither silently reused (wrong results) nor
    // silently re-run (mixed-seed artifact dir).
    let b = Budget {
        iters: 1,
        init: 1,
        pool: 8,
        mc: 8,
        n1: 0,
        k: 0,
    };
    let s = scenario(Phase::Training, 0, None, Explorer::Random, Fidelity::Analytical, b);
    let dir = std::env::temp_dir().join(format!("theseus-campaign-seedswap-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let first = run_campaign(&fresh_cfg(vec![s.clone()], 1, 1)).unwrap();
    write_artifacts(&first, &dir).unwrap();

    let key = first.rows[0].scenario.key();
    let artifact_path = dir.join("scenarios").join(format!("{key}.json"));
    let original = std::fs::read_to_string(&artifact_path).unwrap();

    let resumed = run_campaign(&CampaignConfig {
        scenarios: vec![s],
        seed: 2, // different campaign seed ⇒ different derived seed
        jobs: 1,
        resume_from: Some(dir.clone()),
        shard: None,
    })
    .unwrap();
    let e = resumed.rows[0].outcome.error().expect("must be an error row");
    assert!(e.contains("--seed changed?"), "{e}");
    assert!(e.contains("delete it to re-run"), "{e}");

    // The conflict must never clobber the finished artifact on disk:
    // write_artifacts skips conflict rows, so the original bytes (which
    // the error tells the user to inspect/delete) survive.
    write_artifacts(&resumed, &dir).unwrap();
    assert_eq!(std::fs::read_to_string(&artifact_path).unwrap(), original);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_retries_error_rows_and_guards_the_spec() {
    let b = Budget {
        iters: 1,
        init: 1,
        pool: 8,
        mc: 8,
        n1: 0,
        k: 0,
    };
    let dir = std::env::temp_dir().join(format!("theseus-campaign-retry-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // A recorded error row is not finished work: resume must re-run it.
    // (Here the failure is deterministic — unknown model — so the retry
    // fails again, but as a fresh evaluation, not a replayed artifact.)
    let mut broken = scenario(Phase::Training, 0, None, Explorer::Random, Fidelity::Analytical, b);
    broken.model = "no-such-model".to_string();
    let first = run_campaign(&fresh_cfg(vec![broken.clone()], 9, 1)).unwrap();
    assert_eq!(first.n_errors(), 1);
    write_artifacts(&first, &dir).unwrap();
    let again = run_campaign(&CampaignConfig {
        scenarios: vec![broken],
        seed: 9,
        jobs: 1,
        resume_from: Some(dir.clone()),
        shard: None,
    })
    .unwrap();
    assert!(
        !again.rows[0].outcome.is_resumed(),
        "error artifacts must be retried, not resumed"
    );
    assert!(again.rows[0].outcome.error().is_some());

    // Budget-only changes are invisible in the key (same derived seed),
    // so a finished artifact recorded under a different budget must be a
    // loud error row, not a silent stand-in for the bigger run.
    let ok = scenario(Phase::Training, 0, None, Explorer::Random, Fidelity::Analytical, b);
    let done = run_campaign(&fresh_cfg(vec![ok.clone()], 9, 1)).unwrap();
    write_artifacts(&done, &dir).unwrap();
    let mut bigger = ok;
    bigger.budget.iters = 3;
    let resumed = run_campaign(&CampaignConfig {
        scenarios: vec![bigger],
        seed: 9,
        jobs: 1,
        resume_from: Some(dir.clone()),
        shard: None,
    })
    .unwrap();
    let e = resumed.rows[0].outcome.error().expect("spec mismatch must be loud");
    assert!(e.contains("different scenario spec"), "{e}");
    assert!(e.contains("delete it to re-run"), "{e}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn poisoned_scenarios_do_not_sink_the_campaign() {
    let b = Budget {
        iters: 1,
        init: 1,
        pool: 8,
        mc: 8,
        n1: 0,
        k: 0,
    };
    let mut poisoned = scenario(Phase::Training, 0, None, Explorer::Random, Fidelity::Analytical, b);
    poisoned.model = "no-such-model".to_string();
    let mut scenarios = vec![
        scenario(Phase::Decode, 4, None, Explorer::Random, Fidelity::Analytical, b),
        poisoned,
    ];
    // Unavailable fidelity backend (PJRT GNN without artifacts in the
    // default build): a second failure mode.
    #[cfg(not(theseus_pjrt))]
    scenarios.push(scenario(Phase::Decode, 4, None, Explorer::Random, Fidelity::Gnn, b));
    let cfg = fresh_cfg(scenarios, 7, 2);
    let result = run_campaign(&cfg).unwrap();
    assert_eq!(result.rows.len(), cfg.scenarios.len());
    assert!(result.rows[0].outcome.error().is_none(), "healthy scenario sunk");
    let e = result.rows[1].outcome.error().unwrap();
    assert!(e.contains("unknown model 'no-such-model'"), "{e}");
    #[cfg(not(theseus_pjrt))]
    {
        assert_eq!(result.n_errors(), 2);
        let e = result.rows[2].outcome.error().unwrap();
        assert!(e.contains("fidelity 'gnn' unavailable"), "{e}");
    }

    // The summary records per-row status instead of aborting.
    let sj = summary_json(&result);
    assert_eq!(sj.get("n_errors").unwrap().as_f64(), Some(result.n_errors() as f64));
    let rows = sj.get("scenarios").unwrap().as_arr().unwrap();
    assert_eq!(rows[0].get("status").unwrap().as_str(), Some("ok"));
    assert_eq!(rows[1].get("status").unwrap().as_str(), Some("error"));
    assert!(rows[1].get("error").unwrap().as_str().is_some());
}

#[test]
fn fault_rows_digest_degradation_and_resume_byte_identically() {
    // ISSUE 6 acceptance: fault-injection scenarios are first-class
    // campaign rows — deterministic, resume-safe, and carrying the
    // degradation digest (retained throughput fraction, perf/W per
    // good-wafer cost) in both the per-scenario artifact and the summary.
    let b = Budget {
        iters: 1,
        init: 2,
        pool: 8,
        mc: 8,
        n1: 0,
        k: 0,
    };
    let mut pristine = scenario(Phase::Training, 0, None, Explorer::Random, Fidelity::Analytical, b);
    pristine.fault_defect = Some(0.0); // fault path on, zero defects
    pristine.fault_spares = Some(0);
    let mut defective = pristine.clone();
    defective.fault_defect = Some(2.0);
    let cfg = fresh_cfg(vec![pristine.clone(), defective.clone()], 41, 1);
    let result = run_campaign(&cfg).unwrap();
    assert_eq!(result.n_errors(), 0, "fault rows must evaluate cleanly");

    // Per-scenario artifacts carry the fault digest.
    let docs: Vec<Json> = result.rows.iter().map(scenario_result_json).collect();
    for doc in &docs {
        assert!(doc.get("fault").is_some(), "fault rows must digest");
    }
    let retained = |doc: &Json| {
        doc.get("fault")
            .and_then(|f| f.get("retained_fraction"))
            .and_then(Json::as_f64)
            .unwrap()
    };
    // Zero-defect sampling exercises the fault path but injects nothing:
    // the design retains its full fault-free throughput.
    assert!(
        (retained(&docs[0]) - 1.0).abs() < 1e-12,
        "zero-defect retained fraction {} != 1",
        retained(&docs[0])
    );
    let r2 = retained(&docs[1]);
    assert!(
        r2 > 0.0 && r2 <= 1.0 + 1e-9,
        "defective retained fraction {r2} out of range"
    );
    assert!(
        docs[1]
            .get("fault")
            .and_then(|f| f.get("perf_per_watt_per_wafer"))
            .and_then(Json::as_f64)
            .unwrap()
            > 0.0
    );

    // The summary surfaces the digest per row.
    let rows = summary_json(&result);
    let rows = rows.get("scenarios").unwrap().as_arr().unwrap();
    assert!(rows
        .iter()
        .all(|r| r.get("retained_fraction").and_then(Json::as_f64).is_some()));

    // Resume contract: a resumed fault campaign reads the digest back
    // from disk and serializes byte-identically (modulo the status
    // marker), without re-running the engine.
    let dir = std::env::temp_dir().join(format!("theseus-campaign-fault-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    write_artifacts(&result, &dir).unwrap();
    let resumed = run_campaign(&CampaignConfig {
        scenarios: vec![pristine, defective],
        seed: 41,
        jobs: 1,
        resume_from: Some(dir.clone()),
        shard: None,
    })
    .unwrap();
    assert_eq!(resumed.n_resumed(), 2);
    for (a, b) in result.rows.iter().zip(&resumed.rows) {
        assert_eq!(
            scenario_result_json(a).to_pretty(),
            scenario_result_json(b).to_pretty(),
            "fault artifact for {} diverged through resume",
            a.scenario.key()
        );
    }
    let a = summary_json(&result).to_pretty();
    let b = summary_json(&resumed).to_pretty();
    assert_eq!(a, b.replace("\"status\": \"resumed\"", "\"status\": \"ok\""));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn hetero_scenario_is_a_first_class_campaign_row() {
    // Satellite of ISSUE 6: the tested successor of
    // examples/inference_hetero.rs — a heterogeneous decode scenario runs
    // through the campaign path and round-trips its spec through the
    // scenario JSON schema.
    let b = Budget {
        iters: 1,
        init: 1,
        pool: 8,
        mc: 8,
        n1: 0,
        k: 0,
    };
    let mut s = scenario(Phase::Decode, 8, None, Explorer::Random, Fidelity::Analytical, b);
    s.hetero = Some(theseus::arch::HeteroConfig {
        granularity: theseus::arch::HeteroGranularity::Reticle,
        prefill_ratio: 0.5,
        decode_stack_bw: 2.0,
    });
    assert!(s.key().ends_with("-hreticle"), "{}", s.key());
    let back = Scenario::from_json(&s.to_json()).unwrap();
    assert_eq!(back, s);
    let result = run_campaign(&fresh_cfg(vec![s], 13, 1)).unwrap();
    assert_eq!(result.n_errors(), 0);
    let trace = result.rows[0].outcome.trace().unwrap();
    assert!(!trace.points.is_empty());
    // Hetero rows are not fault rows: no degradation digest.
    assert!(scenario_result_json(&result.rows[0]).get("fault").is_none());
}

/// Three cheap scenarios so 2-way sharding leaves an uneven split
/// (shard 1/2 gets indices 0 and 2, shard 2/2 gets index 1).
fn shardable_scenarios() -> Vec<Scenario> {
    let b = Budget {
        iters: 1,
        init: 1,
        pool: 8,
        mc: 8,
        n1: 0,
        k: 0,
    };
    vec![
        scenario(Phase::Training, 0, None, Explorer::Random, Fidelity::Analytical, b),
        scenario(Phase::Decode, 4, None, Explorer::Random, Fidelity::Analytical, b),
        scenario(Phase::Decode, 8, None, Explorer::Mobo, Fidelity::Analytical, b),
    ]
}

#[test]
fn sharded_then_merged_campaign_is_byte_identical_to_unsharded() {
    // The scale-out contract (ISSUE 7): run the matrix as two shards on
    // "two machines", merge the artifact dirs, and get byte-identical
    // scenario artifacts — and a campaign.json identical modulo the
    // resumed status markers — to a single unsharded run.
    let seed = 2024;
    let scenarios = shardable_scenarios();
    let root = std::env::temp_dir().join(format!("theseus-campaign-shard-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);

    let full = run_campaign(&fresh_cfg(scenarios.clone(), seed, 1)).unwrap();
    assert_eq!(full.n_errors(), 0);
    let dir_full = root.join("full");
    write_artifacts(&full, &dir_full).unwrap();

    let mut shard_dirs = Vec::new();
    for k in 1..=2usize {
        let cfg = CampaignConfig {
            shard: Some((k, 2)),
            ..fresh_cfg(scenarios.clone(), seed, 1)
        };
        let part = run_campaign(&cfg).unwrap();
        assert_eq!(part.rows.len(), if k == 1 { 2 } else { 1 });
        let dir = root.join(format!("shard{k}"));
        write_artifacts(&part, &dir).unwrap();
        // Shard runs declare themselves in their own campaign.json.
        let sj = std::fs::read_to_string(dir.join("campaign.json")).unwrap();
        assert!(sj.contains(&format!("\"shard\": \"{k}/2\"")), "{sj}");
        shard_dirs.push(dir);
    }

    let merged = merge_campaign(&fresh_cfg(scenarios.clone(), seed, 1), &shard_dirs).unwrap();
    assert_eq!(merged.rows.len(), scenarios.len());
    assert_eq!(merged.n_resumed(), scenarios.len(), "all work came from the shards");
    let dir_merged = root.join("merged");
    write_artifacts(&merged, &dir_merged).unwrap();

    for s in &scenarios {
        let name = format!("{}.json", s.key());
        let a = std::fs::read_to_string(dir_full.join("scenarios").join(&name)).unwrap();
        let b = std::fs::read_to_string(dir_merged.join("scenarios").join(&name)).unwrap();
        assert_eq!(a, b, "scenario artifact {name} diverged through shard+merge");
    }
    let a = std::fs::read_to_string(dir_full.join("campaign.json")).unwrap();
    let b = std::fs::read_to_string(dir_merged.join("campaign.json")).unwrap();
    assert_eq!(a, b.replace("\"status\": \"resumed\"", "\"status\": \"ok\""));

    // Interleaved kill/resume leg: shard 1 died before finishing its
    // second scenario (no campaign.json, one artifact missing). Merge
    // re-runs exactly the missing scenario and the bytes still match.
    let dir_killed = root.join("shard1-killed");
    std::fs::create_dir_all(dir_killed.join("scenarios")).unwrap();
    let survivor = format!("{}.json", scenarios[0].key());
    std::fs::copy(
        shard_dirs[0].join("scenarios").join(&survivor),
        dir_killed.join("scenarios").join(&survivor),
    )
    .unwrap();
    let merged2 = merge_campaign(
        &fresh_cfg(scenarios.clone(), seed, 1),
        &[dir_killed, shard_dirs[1].clone()],
    )
    .unwrap();
    assert_eq!(merged2.n_errors(), 0);
    assert_eq!(merged2.n_resumed(), 2, "one scenario must re-run fresh");
    let dir_merged2 = root.join("merged2");
    write_artifacts(&merged2, &dir_merged2).unwrap();
    for s in &scenarios {
        let name = format!("{}.json", s.key());
        let a = std::fs::read_to_string(dir_full.join("scenarios").join(&name)).unwrap();
        let b = std::fs::read_to_string(dir_merged2.join("scenarios").join(&name)).unwrap();
        assert_eq!(a, b, "scenario artifact {name} diverged through kill+merge");
    }

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn merge_rejects_duplicate_and_overlapping_shards() {
    let seed = 5;
    let scenarios = shardable_scenarios();
    let root = std::env::temp_dir().join(format!(
        "theseus-campaign-shard-guards-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);

    let cfg1 = CampaignConfig {
        shard: Some((1, 2)),
        ..fresh_cfg(scenarios.clone(), seed, 1)
    };
    let part = run_campaign(&cfg1).unwrap();
    let dir_a = root.join("a");
    let dir_b = root.join("b");
    write_artifacts(&part, &dir_a).unwrap();
    write_artifacts(&part, &dir_b).unwrap();

    // The same shard supplied twice: caught by the campaign.json shard
    // declaration before any scenario-level probing.
    let e = merge_campaign(&fresh_cfg(scenarios.clone(), seed, 1), &[dir_a.clone(), dir_b.clone()])
        .unwrap_err();
    assert!(e.contains("duplicate shard 1/2"), "{e}");

    // Same overlap with the declaration gone: caught per scenario as
    // overlapping artifacts.
    std::fs::remove_file(dir_b.join("campaign.json")).unwrap();
    let e = merge_campaign(&fresh_cfg(scenarios.clone(), seed, 1), &[dir_a, dir_b]).unwrap_err();
    assert!(e.contains("overlapping shards"), "{e}");
    assert!(e.contains(&scenarios[0].key()), "{e}");

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn merge_reruns_stale_spec_artifacts_fresh() {
    // Incremental re-run: a shard artifact recorded under an older budget
    // (invisible in the key, visible in spec_hash + the recorded spec) is
    // not an error under --merge — it is stale work, re-run fresh.
    let seed = 17;
    let mut scenarios = shardable_scenarios();
    let root = std::env::temp_dir().join(format!(
        "theseus-campaign-shard-stale-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let dir = root.join("old");
    let old = run_campaign(&fresh_cfg(scenarios.clone(), seed, 1)).unwrap();
    write_artifacts(&old, &dir).unwrap();

    scenarios[0].budget.iters += 1; // same key, different spec
    let merged = merge_campaign(&fresh_cfg(scenarios.clone(), seed, 1), &[dir]).unwrap();
    assert_eq!(merged.n_errors(), 0);
    assert_eq!(merged.n_resumed(), scenarios.len() - 1);
    assert!(
        !merged.rows[0].outcome.is_resumed(),
        "stale-spec artifact must re-run fresh under --merge"
    );

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn mqa_scenario_is_a_first_class_campaign_row() {
    // The mqa axis rides the campaign path end to end: its own key (and
    // so artifact file + derived seed), clean evaluation, and a JSON
    // roundtrip through the scenario schema.
    let b = Budget {
        iters: 1,
        init: 1,
        pool: 8,
        mc: 8,
        n1: 0,
        k: 0,
    };
    let mut s = scenario(Phase::Decode, 8, None, Explorer::Random, Fidelity::Analytical, b);
    s.mqa = true;
    assert!(s.key().ends_with("-mqa"), "{}", s.key());
    assert_eq!(Scenario::from_json(&s.to_json()).unwrap(), s);
    let mut base = s.clone();
    base.mqa = false;
    let result = run_campaign(&fresh_cfg(vec![base, s], 23, 1)).unwrap();
    assert_eq!(result.n_errors(), 0);
    let docs: Vec<Json> = result.rows.iter().map(scenario_result_json).collect();
    for doc in &docs {
        assert!(doc.get("trace").is_some());
        assert!(doc.get("spec_hash").and_then(Json::as_str).is_some());
    }
    assert_ne!(
        docs[0].get("spec_hash"),
        docs[1].get("spec_hash"),
        "mqa must be part of the spec identity"
    );
}

#[test]
fn paper_suite_schema_is_golden_pinned() {
    let golden_path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/golden/campaign_suite.json"
    );
    let golden = std::fs::read_to_string(golden_path).unwrap();
    let emitted = suite_to_json(&paper_suite()).to_pretty() + "\n";
    assert_eq!(
        emitted, golden,
        "paper_suite() JSON schema drifted from tests/golden/campaign_suite.json — \
         if the change is intentional, regenerate the golden file so the drift is a reviewed diff"
    );
    // decode → encode round-trips byte-identically...
    let parsed = Json::parse(&golden).unwrap();
    assert_eq!(parsed.to_pretty() + "\n", golden);
    // ...including through the typed Scenario layer.
    let scenarios = scenarios_from_json(&parsed).unwrap();
    assert_eq!(scenarios, paper_suite());
    assert_eq!(suite_to_json(&scenarios).to_pretty() + "\n", golden);
}

#[test]
fn wafer_sweep_suite_schema_is_golden_pinned() {
    let golden_path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/golden/wafer_sweep_suite.json"
    );
    let golden = std::fs::read_to_string(golden_path).unwrap();
    let emitted = suite_to_json(&wafer_sweep_suite()).to_pretty() + "\n";
    assert_eq!(
        emitted, golden,
        "wafer_sweep_suite() JSON schema drifted from tests/golden/wafer_sweep_suite.json — \
         if the change is intentional, regenerate the golden file so the drift is a reviewed diff"
    );
    // decode → encode round-trips byte-identically...
    let parsed = Json::parse(&golden).unwrap();
    assert_eq!(parsed.to_pretty() + "\n", golden);
    // ...including through the typed Scenario layer.
    let scenarios = scenarios_from_json(&parsed).unwrap();
    assert_eq!(scenarios, wafer_sweep_suite());
    assert_eq!(suite_to_json(&scenarios).to_pretty() + "\n", golden);
}

#[test]
fn serving_suite_schema_is_golden_pinned() {
    let golden_path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/golden/serving_suite.json"
    );
    let golden = std::fs::read_to_string(golden_path).unwrap();
    let emitted = suite_to_json(&serving_suite()).to_pretty() + "\n";
    assert_eq!(
        emitted, golden,
        "serving_suite() JSON schema drifted from tests/golden/serving_suite.json — \
         if the change is intentional, regenerate the golden file so the drift is a reviewed diff"
    );
    // decode → encode round-trips byte-identically...
    let parsed = Json::parse(&golden).unwrap();
    assert_eq!(parsed.to_pretty() + "\n", golden);
    // ...including through the typed Scenario layer.
    let scenarios = scenarios_from_json(&parsed).unwrap();
    assert_eq!(scenarios, serving_suite());
    assert_eq!(suite_to_json(&scenarios).to_pretty() + "\n", golden);
}

#[test]
fn interwafer_scenario_is_a_first_class_campaign_row() {
    // The inter-wafer network axis rides the campaign path end to end:
    // its own key suffix (so its own artifact file and derived seed), a
    // JSON roundtrip through the scenario schema, and a clean multi-wafer
    // evaluation that digests scaling efficiency.
    let b = Budget {
        iters: 1,
        init: 2,
        pool: 8,
        mc: 8,
        n1: 0,
        k: 0,
    };
    let mut s = scenario(Phase::Training, 0, Some(2), Explorer::Random, Fidelity::Analytical, b);
    s.interwafer = Some(theseus::arch::InterWaferNet {
        topology: theseus::arch::InterWaferTopology::Ring,
        links_per_wafer: 8,
        link_bandwidth: 100.0e9,
        link_latency: 1.0e-6,
    });
    assert!(s.key().ends_with("-iwring"), "{}", s.key());
    let back = Scenario::from_json(&s.to_json()).unwrap();
    assert_eq!(back, s);
    let result = run_campaign(&fresh_cfg(vec![s], 19, 1)).unwrap();
    assert_eq!(result.n_errors(), 0);
    let doc = scenario_result_json(&result.rows[0]);
    assert!(doc.get("trace").is_some());
    // Fixed-wafer rows carry the scaling digest; interwafer rows are not
    // fault rows, so no degradation digest.
    let scaling = doc.get("scaling").expect("fixed-wafer row must digest scaling");
    assert!(
        scaling
            .get("scaling_efficiency")
            .and_then(Json::as_f64)
            .unwrap()
            > 0.0
    );
    assert!(doc.get("fault").is_none());
}

#[test]
fn cli_unknown_keys_exit_1_listing_options() {
    let bin = env!("CARGO_BIN_EXE_theseus");

    let out = Command::new(bin)
        .args(["dse", "--model", "gpt-nonexistent"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown model 'gpt-nonexistent'"), "{err}");
    assert!(err.contains("GPT-175B"), "must list valid models: {err}");

    let out = Command::new(bin)
        .args(["dse", "--model", "1.7", "--explorer", "bogus"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown explorer 'bogus'"), "{err}");
    assert!(err.contains("random, mobo, mfmobo"), "{err}");

    let out = Command::new(bin)
        .args(["eval", "--model", "nope"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown model 'nope'"));

    let out = Command::new(bin)
        .args(["campaign", "--suite", "imaginary"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown suite 'imaginary'"), "{err}");
    assert!(err.contains("serving"), "must list the serving suite: {err}");
}

#[test]
fn cli_campaign_scenarios_file_end_to_end_with_resume() {
    let bin = env!("CARGO_BIN_EXE_theseus");
    let dir = std::env::temp_dir().join(format!("theseus-campaign-cli-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let scen_file = dir.join("scenarios.json");
    std::fs::write(
        &scen_file,
        r#"{"scenarios": [{"model": "GPT-1.7B", "phase": "decode", "explorer": "random",
            "batch": 4, "iters": 1, "init": 1, "pool": 8, "mc": 8, "n1": 0, "k": 0}]}"#,
    )
    .unwrap();
    let out_dir = dir.join("out");
    let out = Command::new(bin)
        .args([
            "campaign",
            "--scenarios",
            scen_file.to_str().unwrap(),
            "--out",
            out_dir.to_str().unwrap(),
            "--seed",
            "3",
            "--jobs",
            "1",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("Campaign summary"));

    let summary =
        Json::parse(&std::fs::read_to_string(out_dir.join("campaign.json")).unwrap()).unwrap();
    assert_eq!(summary.get("n_errors").unwrap().as_f64(), Some(0.0));
    assert_eq!(summary.get("n_scenarios").unwrap().as_f64(), Some(1.0));
    let key = "gpt-1.7b-decode-random-analytical-b4-wauto";
    let scen_path = out_dir.join("scenarios").join(format!("{key}.json"));
    let scen_doc = Json::parse(&std::fs::read_to_string(&scen_path).unwrap()).unwrap();
    assert_eq!(scen_doc.get("status").unwrap().as_str(), Some("ok"));
    assert!(scen_doc.get("trace").is_some());

    // Second invocation with --resume: the finished scenario is skipped
    // (recorded as a resumed row) and its artifact is unchanged on disk.
    let before = std::fs::read_to_string(&scen_path).unwrap();
    let out = Command::new(bin)
        .args([
            "campaign",
            "--scenarios",
            scen_file.to_str().unwrap(),
            "--out",
            out_dir.to_str().unwrap(),
            "--seed",
            "3",
            "--jobs",
            "1",
            "--resume",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("1 resumed"),
        "stderr must report the resumed count: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let summary =
        Json::parse(&std::fs::read_to_string(out_dir.join("campaign.json")).unwrap()).unwrap();
    let rows = summary.get("scenarios").unwrap().as_arr().unwrap();
    assert_eq!(rows[0].get("status").unwrap().as_str(), Some("resumed"));
    assert_eq!(std::fs::read_to_string(&scen_path).unwrap(), before);
    let _ = std::fs::remove_dir_all(&dir);
}
