//! Integration: load the AOT-compiled GNN through PJRT and check its
//! predictions are usable by the op-level evaluator. Skips (with a note)
//! when `artifacts/` has not been built yet — run `make artifacts`.

use theseus::arch::{CoreConfig, Dataflow};
use theseus::compiler::compile_chunk;
use theseus::eval::op_level::{chunk_latency, NocModel};
use theseus::eval::NocEstimator;
use theseus::runtime::GnnModel;
use theseus::workload::models::benchmarks;
use theseus::workload::{OpGraph, Phase};

fn model() -> Option<GnnModel> {
    match GnnModel::load_default() {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP runtime_gnn tests: {e}");
            None
        }
    }
}

fn chunk(h: usize, w: usize, seq: usize) -> (theseus::compiler::CompiledChunk, CoreConfig) {
    let mut spec = benchmarks()[0].clone();
    spec.seq_len = seq;
    let g = OpGraph::transformer_chunk(&spec, 1, 1, 8, Phase::Prefill, false);
    let core = CoreConfig {
        dataflow: Dataflow::WS,
        mac_num: 512,
        buffer_kb: 128,
        buffer_bw_bits: 256,
        noc_bw_bits: 512,
    };
    (compile_chunk(&g, h, w, &core), core)
}

#[test]
fn gnn_loads_and_predicts() {
    let Some(m) = model() else { return };
    let (ch, core) = chunk(4, 4, 64);
    let waits = m
        .predict_link_waits(&ch, &core)
        .expect("predict")
        .expect("4x4 within padding");
    assert_eq!(waits.len(), 4 * 4 * 4);
    assert!(waits.iter().all(|&w| w.is_finite() && w >= 0.0));
    // Some link should see nonzero predicted waiting under load.
    assert!(
        waits.iter().any(|&w| w > 1e-6),
        "all-zero predictions are suspicious"
    );
}

#[test]
fn gnn_feeds_op_level_evaluation() {
    let Some(m) = model() else { return };
    let (ch, core) = chunk(5, 5, 64);
    let waits = m.link_waits(&ch, &core).expect("waits");
    let gnn = chunk_latency(&ch, &core, 1.0, NocModel::LinkWaits(&waits));
    let ana = chunk_latency(&ch, &core, 1.0, NocModel::Analytical);
    assert!(gnn.cycles > 0.0);
    // GNN and analytical must agree within an order of magnitude (both
    // estimate the same chunk).
    let ratio = gnn.cycles / ana.cycles;
    assert!(ratio > 0.1 && ratio < 10.0, "ratio={ratio}");
}

#[test]
fn gnn_tracks_ca_ordering_better_or_close() {
    // Miniature Fig. 7b: Kendall-tau of GNN vs CA over a few configs.
    let Some(m) = model() else { return };
    let mut gnn_lat = Vec::new();
    let mut ca_lat = Vec::new();
    let configs: &[(usize, usize, usize)] = if cfg!(debug_assertions) {
        &[(3, 3, 32), (4, 4, 32), (4, 3, 16)]
    } else {
        &[(3, 3, 32), (4, 4, 64), (5, 4, 32), (6, 6, 64), (4, 6, 96)]
    };
    for &(h, w, seq) in configs {
        let (ch, core) = chunk(h, w, seq);
        let waits = m.link_waits(&ch, &core).unwrap();
        gnn_lat.push(chunk_latency(&ch, &core, 1.0, NocModel::LinkWaits(&waits)).cycles);
        let stats = theseus::noc_sim::simulate_chunk_result(
            &ch,
            core.noc_bw_bits,
            &|op| {
                theseus::eval::tile::eval_tile(&ch.assignments[op], &core, 1.0)
                    .cycles
                    .ceil() as u64
            },
            300_000_000,
        )
        .expect("CA simulation within budget");
        ca_lat.push(stats.cycles as f64);
    }
    let tau = theseus::util::stats::kendall_tau(&gnn_lat, &ca_lat);
    assert!(tau > 0.0, "gnn should rank-correlate with CA: tau={tau}");
}

#[test]
fn oversize_region_falls_back() {
    let Some(m) = model() else { return };
    let (ch, core) = chunk(17, 17, 32);
    assert!(m.predict_link_waits(&ch, &core).unwrap().is_none());
}

#[test]
fn batched_inference_tracks_per_chunk() {
    // The batcher over the real PJRT executable: batched predictions must
    // match per-chunk predictions (approximately — XLA may reassociate
    // f32 reductions under the vmapped batch program).
    use theseus::runtime::batch::GnnBatcher;
    let Some(m) = model() else { return };
    let built = [chunk(3, 3, 32), chunk(4, 4, 64), chunk(17, 17, 32), chunk(4, 3, 32)];
    let reqs: Vec<(&theseus::compiler::CompiledChunk, &CoreConfig)> =
        built.iter().map(|(c, k)| (c, k)).collect();
    let batched = GnnBatcher::new(&m, 4).link_waits_many(&reqs);
    assert!(batched[2].is_none(), "oversize chunk must fall back");
    for (i, (c, k)) in reqs.iter().enumerate() {
        let direct = m.predict_link_waits(c, k).expect("predict");
        match (&batched[i], &direct) {
            (Some(b), Some(d)) => {
                assert_eq!(b.len(), d.len(), "chunk {i}");
                for (x, y) in b.iter().zip(d) {
                    assert!((x - y).abs() <= 1e-4 * y.abs().max(1.0), "chunk {i}: {x} vs {y}");
                }
            }
            (None, None) => {}
            _ => panic!("chunk {i}: batched/per-chunk fallback disagrees"),
        }
    }
}
