//! Fidelity-registry integration suite: unknown fidelity/phase names exit
//! 1 listing the valid options from the one shared registry (CLI via
//! `CARGO_BIN_EXE_theseus`), `theseus dse --phase decode --fidelity ca`
//! runs end to end, and the new any-fidelity inference path ranks a
//! design pair consistently across fidelities (`THESEUS_TEST_FAST`-aware).

use std::process::Command;

use theseus::design_space::{reference_point, validate};
use theseus::eval::engine::{Engine, EvalSpec, Fidelity};
use theseus::explorer::DesignEval;
use theseus::util::cli::env_flag;
use theseus::workload::models::benchmarks;
use theseus::workload::Phase;

#[test]
fn cli_unknown_fidelity_and_phase_exit_1_listing_registry() {
    let bin = env!("CARGO_BIN_EXE_theseus");

    let out = Command::new(bin)
        .args(["dse", "--model", "1.7", "--fidelity", "warp"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown fidelity 'warp'"), "{err}");
    assert!(
        err.contains("analytical, ca, gnn, gnn-test"),
        "must list the registry names: {err}"
    );

    let out = Command::new(bin)
        .args(["dse", "--model", "1.7", "--phase", "serving"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown phase 'serving'"), "{err}");
    assert!(err.contains("training, prefill, decode"), "{err}");
}

#[test]
fn cli_campaign_scenario_unknown_fidelity_exits_1_with_same_list() {
    // The scenario-JSON path must reject unknown fidelities with the
    // exact registry listing the dse CLI prints — one shared list.
    let bin = env!("CARGO_BIN_EXE_theseus");
    let dir = std::env::temp_dir().join(format!("theseus-fidelity-cli-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let scen_file = dir.join("scenarios.json");
    std::fs::write(
        &scen_file,
        r#"{"scenarios": [{"model": "1.7", "phase": "decode", "explorer": "random",
            "fidelity": "oracle"}]}"#,
    )
    .unwrap();
    let out = Command::new(bin)
        .args([
            "campaign",
            "--scenarios",
            scen_file.to_str().unwrap(),
            "--out",
            dir.join("out").to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown fidelity 'oracle'"), "{err}");
    assert!(err.contains("analytical, ca, gnn, gnn-test"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cli_dse_decode_at_ca_fidelity_end_to_end() {
    // ISSUE 5 acceptance: `theseus dse --phase decode --fidelity ca` runs
    // end to end and writes a trace whose points carry the ca fidelity.
    // THESEUS_CA_BUDGET keeps the per-chunk simulation budget (and so the
    // test) small; overruns take the estimator's documented analytical
    // fallback without changing the trace's fidelity path.
    let bin = env!("CARGO_BIN_EXE_theseus");
    let dir = std::env::temp_dir().join(format!("theseus-dse-ca-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let trace_path = dir.join("trace.json");
    let out = Command::new(bin)
        .args([
            "dse",
            "--model",
            "1.7",
            "--phase",
            "decode",
            "--fidelity",
            "ca",
            "--explorer",
            "random",
            "--iters",
            "1",
            "--init",
            "1",
            "--pool",
            "4",
            "--mc",
            "4",
            "--batch",
            "4",
            "--seed",
            "5",
            "--out",
            trace_path.to_str().unwrap(),
        ])
        .env("THESEUS_CA_BUDGET", "200000")
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("Pareto set"));
    let trace = std::fs::read_to_string(&trace_path).expect("trace written");
    assert!(trace.contains("\"fidelity\": \"ca\""), "{trace}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Shrunken model for the in-process cross-fidelity tests: the CA
/// fidelity simulates the (seq-scaled) prefill chunk, so keep it small —
/// minimal under `THESEUS_TEST_FAST=1` (the bench_check.sh default).
fn small_spec() -> theseus::workload::LlmSpec {
    let mut s = benchmarks()[0].clone();
    let fast = env_flag("THESEUS_TEST_FAST");
    s.seq_len = if fast {
        16
    } else if cfg!(debug_assertions) {
        16
    } else {
        32
    };
    s
}

fn objective_at(
    spec: &theseus::workload::LlmSpec,
    phase: Phase,
    fidelity: Fidelity,
    wafers: usize,
    v: &theseus::design_space::Validated,
) -> theseus::explorer::Objective {
    let engine = Engine::new(
        EvalSpec::inference(spec.clone(), phase, 4)
            .with_fidelity(fidelity)
            .with_wafers(Some(wafers)),
    )
    .expect("registry backend available");
    engine
        .eval(v)
        .unwrap_or_else(|| panic!("{} {} evaluates", fidelity.name(), phase.name()))
}

#[test]
fn decode_ordering_agrees_across_fidelities() {
    // The paper's multi-fidelity loop needs rank agreement at the
    // decision level: a system pair that decode-at-analytical orders one
    // way must order the same way at CA fidelity (the inference path can
    // ride the CA simulator for the first time — ISSUE 5).
    let spec = small_spec();
    let v = validate(&reference_point()).unwrap();
    let ana_small = objective_at(&spec, Phase::Decode, Fidelity::Analytical, 4, &v);
    let ana_big = objective_at(&spec, Phase::Decode, Fidelity::Analytical, 8, &v);
    assert!(
        ana_big.throughput > ana_small.throughput,
        "analytical: {} !> {}",
        ana_big.throughput,
        ana_small.throughput
    );
    let ca_small = objective_at(&spec, Phase::Decode, Fidelity::CycleAccurate, 4, &v);
    let ca_big = objective_at(&spec, Phase::Decode, Fidelity::CycleAccurate, 8, &v);
    assert!(
        ca_big.throughput > ca_small.throughput,
        "ca: {} !> {}",
        ca_big.throughput,
        ca_small.throughput
    );
}

#[test]
fn prefill_ordering_agrees_across_fidelities() {
    // Prefill latency is where the NoC estimator actually bites: a
    // bandwidth-starved NoC must rank below the reference design at both
    // fidelities (the CA estimator really simulating the chunk).
    let spec = small_spec();
    let good = validate(&reference_point()).unwrap();
    let mut weak_point = reference_point();
    weak_point.wsc.reticle.core.noc_bw_bits = 32; // starved NoC
    weak_point.wsc.reticle.core.buffer_bw_bits = 32;
    let weak = validate(&weak_point).expect("weak point still valid");

    let ana_good = objective_at(&spec, Phase::Prefill, Fidelity::Analytical, 1, &good);
    let ana_weak = objective_at(&spec, Phase::Prefill, Fidelity::Analytical, 1, &weak);
    assert!(
        ana_good.throughput > ana_weak.throughput,
        "analytical: {} !> {}",
        ana_good.throughput,
        ana_weak.throughput
    );
    let ca_good = objective_at(&spec, Phase::Prefill, Fidelity::CycleAccurate, 1, &good);
    let ca_weak = objective_at(&spec, Phase::Prefill, Fidelity::CycleAccurate, 1, &weak);
    assert!(
        ca_good.throughput > ca_weak.throughput,
        "ca: {} !> {}",
        ca_good.throughput,
        ca_weak.throughput
    );
}
