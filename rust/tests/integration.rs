//! Cross-module integration tests: full pipeline slices that exercise
//! several layers together (no artifacts required — the GNN-dependent path
//! is covered by rust/tests/runtime_gnn.rs).

use theseus::coordinator::{ref_power_for, run, DseRun, Explorer};
use theseus::design_space::{reference_point, validate};
use theseus::eval::chunk::eval_training_with;
use theseus::eval::engine::Fidelity;
use theseus::eval::{eval_training, Analytical, CycleAccurate, SystemConfig};
use theseus::explorer::BoConfig;
use theseus::workload::models::benchmarks;
use theseus::workload::{ParallelStrategy, Phase};

#[test]
fn validator_to_evaluator_to_explorer() {
    // A miniature random DSE run through the real evaluation engine.
    let spec = benchmarks()[0].clone();
    let dse = DseRun {
        spec: spec.clone(),
        phase: Phase::Training,
        batch: 0,
        mqa: false,
        wafers: None,
        fidelity: Fidelity::Analytical,
        explorer: Explorer::Random,
        cfg: BoConfig {
            iters: 3,
            init: 2,
            pool: 8,
            mc_samples: 8,
            ref_power: ref_power_for(&spec),
            seed: 1,
            sample_tries: 2000,
        },
        n1: 0,
        k: 0,
        faults: None,
    };
    let trace = run(&dse).expect("analytical run builds");
    assert!(trace.points.len() >= 3);
    assert!(trace.final_hv() > 0.0);
    // Every trace point re-validates (the explorer never leaks invalid
    // configurations).
    for p in &trace.points {
        assert!(validate(&p.point).is_ok(), "invalid point in trace");
    }
}

#[test]
fn mobo_improves_over_iterations() {
    let spec = benchmarks()[0].clone();
    let cfg = BoConfig {
        iters: 6,
        init: 4,
        pool: 16,
        mc_samples: 16,
        ref_power: ref_power_for(&spec),
        seed: 5,
        sample_tries: 2000,
    };
    let obj = theseus::eval::engine::Engine::analytical_training(spec);
    let trace = theseus::explorer::mobo(&obj, &cfg);
    assert!(trace.points.len() >= 6);
    // HV after all iterations >= HV after init (monotone by construction,
    // but this checks the plumbing end to end).
    let init_hv = trace.hv_history[cfg.init.min(trace.hv_history.len()) - 1];
    assert!(trace.final_hv() >= init_hv);
}

#[test]
fn analytical_and_ca_fidelities_agree_on_ordering() {
    // Evaluate two very different design points with both fidelities; the
    // better-by-analytical must also be better-by-CA (rank agreement at
    // the decision level — what multi-fidelity optimization needs).
    let spec = {
        let mut s = benchmarks()[0].clone();
        // Keep CA-sim time bounded; debug builds shrink further (the
        // mandated `cargo test` runs unoptimized), and THESEUS_TEST_FAST=1
        // (e.g. from scripts/bench_check.sh) shrinks to the minimum config
        // that still separates the two design points.
        let fast = theseus::util::cli::env_flag("THESEUS_TEST_FAST");
        s.seq_len = if fast {
            16
        } else if cfg!(debug_assertions) {
            32
        } else {
            64
        };
        s.batch_size = if cfg!(debug_assertions) || fast { 8 } else { 16 };
        s
    };
    // One fixed strategy: the CA fidelity is too expensive for the full
    // §VI-A strategy sweep in a test.
    let strat = ParallelStrategy { tp: 2, pp: 1, dp: 4, microbatch: 2 };
    let good = validate(&reference_point()).unwrap();
    let mut weak_point = reference_point();
    weak_point.wsc.reticle.core.noc_bw_bits = 32; // starved NoC
    weak_point.wsc.reticle.core.buffer_bw_bits = 32;
    let weak = validate(&weak_point).expect("weak point still valid");

    let ana_good = eval_training_with(
        &spec,
        &SystemConfig {
            validated: good.clone(),
            n_wafers: 1,
            faults: None,
        },
        strat,
        &Analytical,
    )
    .unwrap()
    .tokens_per_sec;
    let ana_weak = eval_training_with(
        &spec,
        &SystemConfig {
            validated: weak.clone(),
            n_wafers: 1,
            faults: None,
        },
        strat,
        &Analytical,
    )
    .unwrap()
    .tokens_per_sec;
    assert!(ana_good > ana_weak, "analytical: {ana_good} !> {ana_weak}");

    let ca = CycleAccurate {
        max_cycles: 400_000_000,
    };
    let ca_good = eval_training_with(
        &spec,
        &SystemConfig {
            validated: good,
            n_wafers: 1,
            faults: None,
        },
        strat,
        &ca,
    )
    .unwrap()
    .tokens_per_sec;
    let ca_weak = eval_training_with(
        &spec,
        &SystemConfig {
            validated: weak,
            n_wafers: 1,
            faults: None,
        },
        strat,
        &ca,
    )
    .unwrap()
    .tokens_per_sec;
    assert!(ca_good > ca_weak, "CA: {ca_good} !> {ca_weak}");
}

#[test]
fn paper_takeaway_1_core_granularity_has_interior_optimum() {
    // Tiny Fig. 9 run: mid-range core granularity must beat tiny cores
    // (the paper's optimum is 512G-1T FLOPS).
    let per_grid = if cfg!(debug_assertions) { 2 } else { 4 };
    let (_, rows) = theseus::figures::fig9_core_granularity(0, per_grid, 7);
    let by_mac = |gflops: f64| {
        rows.iter()
            .filter(|r| (r.core_gflops - gflops).abs() < 1.0)
            .map(|r| r.best_throughput)
            .fold(0.0f64, f64::max)
    };
    let tiny = by_mac(16.0); // 8 MACs
    let mid = by_mac(1024.0).max(by_mac(2048.0)).max(by_mac(512.0));
    assert!(
        mid > tiny,
        "mid-granularity ({mid}) should beat tiny cores ({tiny})"
    );
}

#[test]
fn paper_takeaway_2_kgd_yield_mechanism() {
    // Takeaway 2's mechanism: without KGD screening, die stitching must
    // multiply reticle yields, so at realistic reticle counts it needs
    // strictly more redundancy than InFO-SoW — or cannot reach the target
    // at all. (Our reproduction finds the paper's blanket "InFO-SoW always
    // wins" does NOT hold at small reticle counts, where stitching's
    // cheaper PHY dominates — see EXPERIMENTS.md Fig. 9 notes.)
    use theseus::arch::IntegrationStyle;
    let p = reference_point(); // 54 reticles of 12x12 cores
    let info = validate(&p).expect("InfoSoW reference validates");
    let mut stitched = p;
    stitched.wsc.integration = IntegrationStyle::DieStitching;
    match validate(&stitched) {
        Ok(v) => assert!(
            v.phys.reticle.red_per_row > info.phys.reticle.red_per_row,
            "stitching at 54 reticles must pay more redundancy ({} vs {})",
            v.phys.reticle.red_per_row,
            info.phys.reticle.red_per_row
        ),
        Err(e) => {
            // Equally consistent: the yield target is simply unreachable.
            let msg = format!("{e}");
            assert!(msg.contains("yield"), "unexpected failure: {msg}");
        }
    }
}

#[test]
fn equal_area_system_sizing() {
    let v = validate(&reference_point()).unwrap();
    let spec = benchmarks()[7].clone(); // 1000 GPUs
    let sys = SystemConfig::area_matched(v, spec.gpu_num);
    let gpu_area = spec.gpu_num as f64 * theseus::baselines::H100_DIE_MM2;
    let wsc_area = sys.n_wafers as f64 * sys.validated.phys.area_mm2;
    let ratio = wsc_area / gpu_area;
    assert!(ratio > 0.8 && ratio < 1.2, "area mismatch ratio {ratio}");
}
