"""Rust-aware source scanner for theseus-lint.

Not a parser — a character-level scanner that is exact about the three
things a naive grep gets wrong:

* **Literals and comments**: the contents of string literals (plain, raw
  ``r"..."``/``r#"..."#`` with any hash depth, byte, byte-raw), char
  literals (including escapes, and disambiguated from lifetimes), line
  comments and (nested) block comments are *masked* — replaced by spaces,
  preserving length and newlines — before any rule regex runs. A doc
  string containing ``unwrap()`` can never trip the panic rule.
* **Test regions**: brace-matched spans introduced by ``#[cfg(test)]``
  (on a ``mod``/``fn``/``impl``/any item), bare ``mod tests { .. }``
  blocks, and ``#[test]`` functions are marked so rules can exempt them.
  Brace matching runs on the masked text, so braces inside strings don't
  desynchronize it.
* **Suppressions**: ``// lint: allow(<rule>) <reason>`` comments are
  parsed from the *raw* text (they live inside comments, which the mask
  erases). A suppression covers its own line and, when the comment is the
  whole line, the next non-comment line. A missing reason is itself a
  lint error — a bare allow tells the next reader nothing.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field


@dataclass
class ScannedFile:
    """One source file, scanned once and shared by every rule."""

    path: str
    raw: str
    masked: str
    # 1-based line -> masked text of that line.
    masked_lines: list[str] = field(default_factory=list)
    # 1-based line numbers inside test regions.
    test_lines: set[int] = field(default_factory=set)
    # rule name -> set of 1-based lines a suppression covers.
    suppressed: dict[str, set[int]] = field(default_factory=dict)
    # (line, message) pairs for malformed suppression comments.
    suppression_errors: list[tuple[int, str]] = field(default_factory=list)

    def is_test_line(self, line: int) -> bool:
        return line in self.test_lines

    def is_suppressed(self, rule: str, line: int) -> bool:
        return line in self.suppressed.get(rule, set())


def mask_source(text: str) -> str:
    """Return ``text`` with the contents of strings, chars and comments
    replaced by spaces (newlines kept, same total length)."""
    out = list(text)
    i, n = 0, len(text)

    def blank(a: int, b: int) -> None:
        for j in range(a, b):
            if out[j] != "\n":
                out[j] = " "

    while i < n:
        c = text[i]
        nxt = text[i + 1] if i + 1 < n else ""

        if c == "/" and nxt == "/":  # line comment
            j = text.find("\n", i)
            j = n if j == -1 else j
            blank(i, j)
            i = j
        elif c == "/" and nxt == "*":  # block comment (Rust nests these)
            depth, j = 1, i + 2
            while j < n and depth:
                if text.startswith("/*", j):
                    depth += 1
                    j += 2
                elif text.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    j += 1
            blank(i, j)
            i = j
        elif c in "rb" and _raw_string_at(text, i):  # r"", r#""#, br#""#
            j = _skip_raw_string(text, i)
            blank(i, j)
            i = j
        elif c == "b" and nxt == '"':  # byte string
            j = _skip_plain_string(text, i + 1)
            blank(i, j)
            i = j
        elif c == '"':  # plain string
            j = _skip_plain_string(text, i)
            blank(i, j)
            i = j
        elif c == "'":  # char literal vs lifetime
            j = _char_literal_end(text, i)
            if j is not None:
                blank(i, j)
                i = j
            else:
                i += 1  # lifetime: leave untouched
        else:
            i += 1
    return "".join(out)


def _raw_string_at(text: str, i: int) -> bool:
    """True when position ``i`` starts a raw (or byte-raw) string."""
    j = i
    if text[j] == "b":
        j += 1
    if j >= len(text) or text[j] != "r":
        return False
    j += 1
    while j < len(text) and text[j] == "#":
        j += 1
    # Exclude identifiers like `radius` or the `r#keyword` raw idents.
    if j < len(text) and text[j] == '"':
        # `r#"` is a raw string; `r#ident` was excluded by the '"' check.
        # Guard against matching inside identifiers, e.g. `var"` cannot
        # occur, but `attr` / `br` prefixes of longer idents can:
        if i > 0 and (text[i - 1].isalnum() or text[i - 1] == "_"):
            return False
        return True
    return False


def _skip_raw_string(text: str, i: int) -> int:
    j = i
    if text[j] == "b":
        j += 1
    j += 1  # the 'r'
    hashes = 0
    while text[j] == "#":
        hashes += 1
        j += 1
    j += 1  # the opening quote
    close = '"' + "#" * hashes
    k = text.find(close, j)
    return len(text) if k == -1 else k + len(close)


def _skip_plain_string(text: str, quote: int) -> int:
    j = quote + 1
    n = len(text)
    while j < n:
        if text[j] == "\\":
            j += 2
        elif text[j] == '"':
            return j + 1
        else:
            j += 1
    return n


def _char_literal_end(text: str, i: int) -> int | None:
    """End index (exclusive) of a char literal starting at ``i``, or None
    when the quote starts a lifetime (``'a``, ``'static``)."""
    n = len(text)
    if i + 1 >= n:
        return None
    if text[i + 1] == "\\":  # escaped char: '\n', '\u{1F600}', '\''
        j = i + 2
        if j < n and text[j] == "u":  # '\u{...}'
            k = text.find("}", j)
            if k != -1 and k + 1 < n and text[k + 1] == "'":
                return k + 2
        else:
            j += 1  # the escaped character
            if j < n and text[j] == "'":
                return j + 1
        return None
    # Unescaped: exactly one character then a closing quote.
    if i + 2 < n and text[i + 2] == "'" and text[i + 1] != "'":
        return i + 3
    return None


_CFG_TEST_RE = re.compile(r"#\s*\[\s*cfg\s*\(\s*test\s*\)\s*\]")
_TEST_ATTR_RE = re.compile(r"#\s*\[\s*test\s*\]")
_MOD_TESTS_RE = re.compile(r"\bmod\s+tests\s*\{")
_ATTR_RE = re.compile(r"\s*#\s*\[")


def _line_of(text: str, pos: int) -> int:
    return text.count("\n", 0, pos) + 1


def _match_brace_span(masked: str, open_idx: int) -> int:
    """Index just past the ``}`` matching the ``{`` at ``open_idx``."""
    depth = 0
    for j in range(open_idx, len(masked)):
        if masked[j] == "{":
            depth += 1
        elif masked[j] == "}":
            depth -= 1
            if depth == 0:
                return j + 1
    return len(masked)


def _skip_attrs(masked: str, i: int) -> int:
    """Skip whitespace and further ``#[...]`` attributes from ``i``."""
    n = len(masked)
    while i < n:
        while i < n and masked[i].isspace():
            i += 1
        m = _ATTR_RE.match(masked, i)
        if not m:
            break
        # Attributes can contain nested brackets: #[cfg(all(test, foo))].
        depth = 0
        j = masked.find("[", i)
        for j in range(j, n):
            if masked[j] == "[":
                depth += 1
            elif masked[j] == "]":
                depth -= 1
                if depth == 0:
                    break
        i = j + 1
    return i


def find_test_regions(masked: str) -> set[int]:
    """1-based line numbers covered by test-only code."""
    lines: set[int] = set()

    def mark(a: int, b: int) -> None:
        lines.update(range(_line_of(masked, a), _line_of(masked, min(b, len(masked) - 1)) + 1))

    for m in list(_CFG_TEST_RE.finditer(masked)) + list(_TEST_ATTR_RE.finditer(masked)):
        item = _skip_attrs(masked, m.end())
        brace = masked.find("{", item)
        semi = masked.find(";", item)
        if semi != -1 and (brace == -1 or semi < brace):
            # `#[cfg(test)] mod tests;` — out-of-line file, handled by the
            # per-path allowlist; nothing to mark here.
            continue
        if brace == -1:
            continue
        mark(m.start(), _match_brace_span(masked, brace) - 1)

    for m in _MOD_TESTS_RE.finditer(masked):
        brace = masked.find("{", m.start())
        mark(m.start(), _match_brace_span(masked, brace) - 1)
    return lines


# `// lint: allow(<rule>) <reason>`; reason is mandatory.
_SUPPRESS_RE = re.compile(r"//\s*lint:\s*allow\(([a-z-]+)\)\s*(.*)$")


def find_suppressions(
    raw_lines: list[str], known_rules: set[str]
) -> tuple[dict[str, set[int]], list[tuple[int, str]]]:
    suppressed: dict[str, set[int]] = {}
    errors: list[tuple[int, str]] = []
    for idx, line in enumerate(raw_lines, start=1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        rule, reason = m.group(1), m.group(2).strip()
        if rule not in known_rules:
            errors.append(
                (idx, f"suppression names unknown rule '{rule}' (known: {', '.join(sorted(known_rules))})")
            )
            continue
        if not reason:
            errors.append((idx, f"suppression for '{rule}' has no reason — say why the site is safe"))
            continue
        covered = suppressed.setdefault(rule, set())
        covered.add(idx)
        if line.lstrip().startswith("//"):
            covered.add(idx + 1)  # standalone comment covers the next line
    return suppressed, errors


def scan_file(path: str, text: str, known_rules: set[str]) -> ScannedFile:
    masked = mask_source(text)
    raw_lines = text.splitlines()
    suppressed, errors = find_suppressions(raw_lines, known_rules)
    return ScannedFile(
        path=path,
        raw=text,
        masked=masked,
        masked_lines=masked.splitlines(),
        test_lines=find_test_regions(masked),
        suppressed=suppressed,
        suppression_errors=errors,
    )
