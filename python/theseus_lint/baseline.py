"""Ratchet baseline: accepted per-rule, per-file violation counts.

The repo predates the linter (238 `unwrap`/`expect` sites at the initial
scan), so the pass ships with a checked-in baseline
(`scripts/lint_baseline.json`) of accepted counts. The contract:

* A count **above** baseline fails immediately, listing the violations —
  new debt never lands.
* A count **below** baseline also fails, telling you to run
  ``--update-baseline`` — improvements must be locked in, or the slack
  would let new debt hide under old headroom.
* ``--update-baseline`` regenerates the file from the current scan. It
  refuses to *grow* any (rule, file) entry (fix the violation instead);
  ``--allow-baseline-growth`` overrides for genuine resets.
* ``_meta.initial_scan`` preserves the per-rule totals of the very first
  scan, so the ratchet's progress is visible in the file itself.
"""

from __future__ import annotations

import json
from collections import Counter

from .rules import RULES, Violation


def counts_of(violations: list[Violation]) -> dict[str, dict[str, int]]:
    per: dict[str, Counter] = {rule: Counter() for rule in RULES}
    for v in violations:
        if v.rule in per:
            per[v.rule][v.path] += 1
    return {
        rule: {path: n for path, n in sorted(files.items())}
        for rule, files in per.items()
    }


def load(path: str) -> dict:
    with open(path, encoding="utf-8") as fh:
        doc = json.load(fh)
    if "rules" not in doc or not isinstance(doc["rules"], dict):
        raise ValueError(f"{path}: malformed baseline — no 'rules' object")
    return doc


def render(counts: dict[str, dict[str, int]], meta: dict) -> str:
    doc = {"_meta": meta, "rules": counts}
    return json.dumps(doc, indent=2, sort_keys=True) + "\n"


def totals(counts: dict[str, dict[str, int]]) -> dict[str, int]:
    return {rule: sum(files.values()) for rule, files in counts.items()}


def compare(
    current: dict[str, dict[str, int]],
    baseline: dict[str, dict[str, int]],
    violations: list[Violation],
) -> list[str]:
    """Human-readable failures; empty means the scan matches the baseline."""
    problems: list[str] = []
    for rule in RULES:
        cur = current.get(rule, {})
        base = baseline.get(rule, {})
        for path in sorted(set(cur) | set(base)):
            c, b = cur.get(path, 0), base.get(path, 0)
            if c > b:
                listing = "\n".join(
                    "    " + v.render()
                    for v in violations
                    if v.rule == rule and v.path == path
                )
                problems.append(
                    f"[{rule}] {path}: {c} violation(s), baseline accepts {b} — new debt:\n{listing}"
                )
            elif c < b:
                problems.append(
                    f"[{rule}] {path}: {c} violation(s), baseline still records {b} — "
                    "improvement not locked in; run scripts/lint_theseus.py --update-baseline"
                )
    return problems


def check_no_growth(
    new: dict[str, dict[str, int]], old: dict[str, dict[str, int]]
) -> list[str]:
    grew: list[str] = []
    for rule in RULES:
        for path, n in new.get(rule, {}).items():
            if n > old.get(rule, {}).get(path, 0):
                grew.append(
                    f"[{rule}] {path}: {old.get(rule, {}).get(path, 0)} -> {n}"
                )
    return grew
