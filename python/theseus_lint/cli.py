"""theseus-lint driver: scan rust/src, apply rules, enforce the baseline.

See ``scripts/lint_theseus.py --help`` for the user-facing contract; this
module is the implementation so `python/tests/test_lint.py` can drive it
in-process against fixture trees.
"""

from __future__ import annotations

import argparse
import os
import sys

from . import baseline as bl
from .rules import RULES, check_all
from .tokenizer import scan_file

HELP_EPILOG = """\
rules:
  panic          unwrap()/expect()/panic!/unreachable!/todo!/unimplemented!
                 banned in non-test library code (propagate Result instead).
                 Exempt: main.rs (CLI exit paths), noc_sim/reference.rs
                 (frozen oracle), noc_sim/tests.rs, test code. assert! stays
                 allowed — contract assertions are loud by design.
  determinism    Instant::now/SystemTime/UNIX_EPOCH and nondeterministic RNG
                 sources (thread_rng/OsRng/from_entropy/getrandom/RandomState)
                 banned in library code; HashMap/HashSet banned in the
                 artifact-writing modules (util/json.rs, coordinator/,
                 figures/) — iteration order must never reach serialized
                 output. Exempt: bench.rs, main.rs (stderr-only timing).
  loud-failure   raw env::var banned outside util/cli.rs (typed env_* helpers
                 warn once on malformed values); bare eprintln! banned in
                 library code outside util/warn.rs (use warn_once).
  stub-coverage  every pub fn / pub type of runtime/pjrt.rs needs a
                 runtime/stub.rs counterpart; a #[cfg(theseus_pjrt)] gate
                 needs a #[cfg(not(theseus_pjrt))] sibling in the same file.

suppressions:
  // lint: allow(<rule>) <reason>
                 on the offending line, or alone on the line above. The
                 reason is mandatory (an unexplained allow is itself an
                 error); use it to record the infallibility proof or why
                 the site cannot reach an artifact.

baseline ratchet (scripts/lint_baseline.json):
  The repo predates the linter, so per-(rule, file) counts of accepted
  legacy violations are checked in. Counts above baseline fail with the
  new violations listed; counts below baseline fail too, telling you to
  lock the improvement in. After burning down violations (or adding a
  justified suppression), run:

      scripts/lint_theseus.py --update-baseline

  and commit the shrunken file. --update-baseline refuses to grow any
  entry (fix the code instead); --allow-baseline-growth overrides for
  genuine resets. The baseline's _meta.initial_scan records the very
  first scan's totals so progress stays visible.
"""


def scan_tree(root: str) -> dict:
    """Scan every .rs file under <root>/rust/src."""
    src = os.path.join(root, "rust", "src")
    files = {}
    for dirpath, _, names in sorted(os.walk(src)):
        for name in sorted(names):
            if not name.endswith(".rs"):
                continue
            path = os.path.join(dirpath, name)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path, encoding="utf-8") as fh:
                files[rel] = scan_file(rel, fh.read(), set(RULES))
    return files


def run(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="lint_theseus.py",
        description=(
            "theseus-lint: toolchain-free static analysis enforcing the "
            "determinism and loud-failure contracts over rust/src."
        ),
        epilog=HELP_EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    parser.add_argument(
        "--root",
        default=None,
        help="repo root (default: the directory containing scripts/)",
    )
    parser.add_argument(
        "--baseline",
        default=None,
        help="baseline file (default: <root>/scripts/lint_baseline.json)",
    )
    parser.add_argument(
        "--update-baseline",
        action="store_true",
        help="regenerate the baseline from the current scan (shrink-only)",
    )
    parser.add_argument(
        "--allow-baseline-growth",
        action="store_true",
        help="let --update-baseline grow entries (genuine resets only)",
    )
    parser.add_argument(
        "--list",
        action="store_true",
        help="print every current violation, including baselined ones",
    )
    args = parser.parse_args(argv)

    # Default root: scripts/lint_theseus.py lives one level below the repo
    # root; in-process callers (tests) pass --root explicitly.
    root = args.root or os.path.dirname(os.path.dirname(os.path.abspath(sys.argv[0])))
    src = os.path.join(root, "rust", "src")
    if not os.path.isdir(src):
        print(f"lint: no rust/src under {root} — wrong --root?", file=sys.stderr)
        return 2
    baseline_path = args.baseline or os.path.join(root, "scripts", "lint_baseline.json")

    files = scan_tree(root)
    violations = check_all(files)

    # Suppression-syntax errors are config bugs: never baselineable.
    config_errors = [v for v in violations if v.rule == "suppression"]
    if config_errors:
        for v in config_errors:
            print(v.render(), file=sys.stderr)
        print(f"lint: {len(config_errors)} malformed suppression(s)", file=sys.stderr)
        return 1
    current = bl.counts_of(violations)

    if args.list:
        for v in violations:
            print(v.render())
        for rule, total in sorted(bl.totals(current).items()):
            print(f"lint: [{rule}] {total} violation(s) across rust/src")

    if args.update_baseline:
        meta = {
            "generated_by": "scripts/lint_theseus.py --update-baseline",
            "initial_scan": bl.totals(current),
        }
        if os.path.exists(baseline_path):
            old = bl.load(baseline_path)
            meta["initial_scan"] = old.get("_meta", {}).get(
                "initial_scan", bl.totals(current)
            )
            grew = bl.check_no_growth(current, old["rules"])
            if grew and not args.allow_baseline_growth:
                for g in grew:
                    print(f"lint: baseline would grow: {g}", file=sys.stderr)
                print(
                    "lint: the baseline may only shrink — fix the new violations, "
                    "or pass --allow-baseline-growth for a genuine reset",
                    file=sys.stderr,
                )
                return 1
        with open(baseline_path, "w", encoding="utf-8") as fh:
            fh.write(bl.render(current, meta))
        print(f"lint: baseline written to {baseline_path}")
        for rule, total in sorted(bl.totals(current).items()):
            print(f"lint:   [{rule}] {total} accepted violation(s)")
        return 0

    if not os.path.exists(baseline_path):
        # No baseline at all: only a fully clean tree passes. Anything else
        # needs an explicit decision (--update-baseline), never a silent one.
        if violations:
            for v in violations:
                print(v.render(), file=sys.stderr)
            print(
                f"lint: {len(violations)} violation(s) and no baseline at "
                f"{baseline_path} — fix them or record them with --update-baseline",
                file=sys.stderr,
            )
            return 1
        print("lint: clean (no baseline needed)")
        return 0

    try:
        doc = bl.load(baseline_path)
    except (ValueError, OSError, KeyError) as e:
        print(f"lint: cannot read baseline: {e}", file=sys.stderr)
        return 2
    problems = bl.compare(current, doc["rules"], violations)
    if problems:
        for p in problems:
            print(p, file=sys.stderr)
        print(f"lint: FAILED ({len(problems)} baseline mismatch(es))", file=sys.stderr)
        return 1
    shown = bl.totals(current)
    print(
        "lint: OK — "
        + ", ".join(f"{rule}: {shown[rule]} baselined" for rule in RULES)
    )
    return 0
