"""theseus-lint: toolchain-free static analysis for the Theseus Rust tree.

The only correctness gate that executes in every build container (some
ship no cargo/rustc — see CHANGES.md): a Rust-aware scanner enforcing the
panic-freedom, determinism, loud-failure and stub-coverage contracts as
named rules behind a shrink-only baseline ratchet. Entry point:
``scripts/lint_theseus.py`` (or ``python -m theseus_lint.cli`` logic via
:func:`theseus_lint.cli.run`).
"""

from .cli import run, scan_tree
from .rules import RULES, Violation, check_all
from .tokenizer import ScannedFile, mask_source, scan_file

__all__ = [
    "RULES",
    "ScannedFile",
    "Violation",
    "check_all",
    "mask_source",
    "run",
    "scan_file",
    "scan_tree",
]
