"""The four theseus-lint rules and their project-level exemptions.

Every rule is a pure function over a [`ScannedFile`] returning
[`Violation`]s. Scope and rationale (see the module docs of the Rust
modules they police, and ISSUE 8):

``panic``
    `unwrap()` / `expect(` / `panic!` / `unreachable!` / `todo!` /
    `unimplemented!` are banned in non-test library code: library paths
    must propagate `Result` (the `SimError` pattern) so a campaign row
    records an error instead of sinking the process. `assert!` family
    stays allowed — contract assertions are loud by design. Exempt:
    `main.rs` (the CLI's documented exit-1 paths), the frozen
    `noc_sim/reference.rs` oracle (bit-identical contract — never edited),
    and test code.

``determinism``
    Wall-clock (`Instant::now`, `SystemTime`, `UNIX_EPOCH`) and
    nondeterministic RNG sources (`thread_rng`, `OsRng`, `from_entropy`,
    `getrandom`, `rand::`, `RandomState`) are banned in library code —
    campaign artifacts must be byte-identical across same-seed runs, and
    every RNG stream must derive from an explicit `u64` seed through
    `util/rng`. Additionally, `HashMap`/`HashSet` are banned in the
    artifact-writing modules (`util/json.rs`, `coordinator/`, `figures/`):
    their iteration order is nondeterministic across processes, and those
    modules feed serialized output — use `BTreeMap`/sorted `Vec`s. Exempt:
    `bench.rs` and `main.rs` (wall-clock progress reporting on stderr
    never reaches an artifact).

``loud-failure``
    Raw `env::var` reads are banned outside `util/cli.rs`: the typed
    helpers there (`env_usize`/`env_u64`/`env_f64`/`env_flag`) warn once
    on set-but-malformed values instead of silently defaulting. Bare
    `eprintln!` is banned in library code outside `util/warn.rs` /
    `util/cli.rs` (the warn infrastructure itself) and the CLI surfaces
    (`main.rs`, `bench.rs`): fallback reporting must ride
    `util::warn::warn_once` so campaigns aren't flooded and the
    first-occurrence contract holds.

``stub-coverage``
    The PJRT runtime (`runtime/pjrt.rs`, behind `--cfg theseus_pjrt`) and
    its offline stand-in (`runtime/stub.rs`) must stay API-parallel: every
    `pub fn` / `pub struct` of the real implementation needs a stub-side
    counterpart, or the offline build rots the moment a caller uses the
    new API under the cfg. Also, a positive `#[cfg(theseus_pjrt)]` gate in
    any file requires a `#[cfg(not(theseus_pjrt))]` sibling in the same
    file (a positive-only gate compiles to nothing offline — silently).
"""

from __future__ import annotations

import re
from dataclasses import dataclass

from .tokenizer import ScannedFile

RULES = ("panic", "determinism", "loud-failure", "stub-coverage")


@dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


# Per-rule path exemptions (path fragments relative to the repo root,
# matched as suffixes of the scanned path).
EXEMPT = {
    "panic": (
        "rust/src/main.rs",            # CLI: documented eprintln+exit(1) paths
        "rust/src/noc_sim/reference.rs",  # frozen per-cycle oracle, never edited
        "rust/src/noc_sim/tests.rs",   # #[cfg(test)] mod in its own file
    ),
    "determinism": (
        "rust/src/bench.rs",           # bench harness: wall-clock timing is the point
        "rust/src/main.rs",            # stderr elapsed reporting, never in artifacts
        "rust/src/noc_sim/tests.rs",
    ),
    "loud-failure": (
        "rust/src/util/cli.rs",        # owns env::var + the malformed-env warning
        "rust/src/util/warn.rs",       # owns the warn-once eprintln
        "rust/src/main.rs",            # CLI: user-facing stderr
        "rust/src/bench.rs",
        "rust/src/noc_sim/tests.rs",
    ),
    "stub-coverage": (),
}

# Modules whose output is serialized into campaign/bench artifacts: hash
# iteration order must not exist there at all.
ARTIFACT_MODULES = ("rust/src/util/json.rs", "rust/src/coordinator/", "rust/src/figures/")

_PANIC_TOKENS = [
    (re.compile(r"\.unwrap\(\)"), "`.unwrap()` in a library path — propagate Result (SimError pattern)"),
    (re.compile(r"\.expect\s*\("), "`.expect(...)` in a library path — propagate Result (SimError pattern)"),
    (re.compile(r"\bpanic!\s*\("), "`panic!` in a library path — return Err instead"),
    (re.compile(r"\bunreachable!\s*\("), "`unreachable!` in a library path — restructure or suppress with a proof"),
    (re.compile(r"\btodo!\s*\("), "`todo!` must not ship"),
    (re.compile(r"\bunimplemented!\s*\("), "`unimplemented!` must not ship"),
]

_DETERMINISM_TOKENS = [
    (re.compile(r"\bInstant::now\b"), "wall-clock (`Instant::now`) in library code — artifacts/seeds must not see time"),
    (re.compile(r"\bSystemTime\b"), "wall-clock (`SystemTime`) in library code"),
    (re.compile(r"\bUNIX_EPOCH\b"), "wall-clock (`UNIX_EPOCH`) in library code"),
    (re.compile(r"\bthread_rng\b"), "nondeterministic RNG (`thread_rng`) — seed `util::rng::Rng` explicitly"),
    (re.compile(r"\bOsRng\b"), "nondeterministic RNG (`OsRng`)"),
    (re.compile(r"\bfrom_entropy\b"), "nondeterministic RNG seeding (`from_entropy`)"),
    (re.compile(r"\bgetrandom\b"), "nondeterministic RNG source (`getrandom`)"),
    (re.compile(r"\bRandomState\b"), "nondeterministic hasher (`RandomState`)"),
]

_HASH_TOKENS = [
    (re.compile(r"\bHashMap\b"), "`HashMap` in an artifact-writing module — iteration order leaks; use BTreeMap"),
    (re.compile(r"\bHashSet\b"), "`HashSet` in an artifact-writing module — iteration order leaks; use BTreeSet"),
]

_LOUD_TOKENS = [
    (re.compile(r"\benv::var\b"), "raw `env::var` outside util/cli — use the typed env_* helpers (loud on malformed values)"),
    (re.compile(r"\beprintln!\s*\("), "bare `eprintln!` in library code — report through util::warn::warn_once"),
]

_PUB_FN_RE = re.compile(r"^\s*pub(?:\s*\([^)]*\))?\s+fn\s+(\w+)", re.MULTILINE)
_PUB_TYPE_RE = re.compile(r"^\s*pub(?:\s*\([^)]*\))?\s+(?:struct|enum)\s+(\w+)", re.MULTILINE)
_CFG_PJRT_POS_RE = re.compile(r"#\s*\[\s*cfg\s*\(\s*theseus_pjrt\s*\)\s*\]")
_CFG_PJRT_NEG_RE = re.compile(r"#\s*\[\s*cfg\s*\(\s*not\s*\(\s*theseus_pjrt\s*\)\s*\)\s*\]")


def _exempt(rule: str, path: str) -> bool:
    return any(path.endswith(frag) or frag in path for frag in EXEMPT[rule])


def _scan_tokens(f: ScannedFile, rule: str, tokens) -> list[Violation]:
    out: list[Violation] = []
    if _exempt(rule, f.path):
        return out
    for lineno, text in enumerate(f.masked_lines, start=1):
        if f.is_test_line(lineno) or f.is_suppressed(rule, lineno):
            continue
        for rx, msg in tokens:
            for _ in rx.finditer(text):
                out.append(Violation(rule, f.path, lineno, msg))
    return out


def check_panic(f: ScannedFile) -> list[Violation]:
    return _scan_tokens(f, "panic", _PANIC_TOKENS)


def check_determinism(f: ScannedFile) -> list[Violation]:
    out = _scan_tokens(f, "determinism", _DETERMINISM_TOKENS)
    if any(frag in f.path for frag in ARTIFACT_MODULES):
        out.extend(_scan_tokens(f, "determinism", _HASH_TOKENS))
    return out


def check_loud_failure(f: ScannedFile) -> list[Violation]:
    return _scan_tokens(f, "loud-failure", _LOUD_TOKENS)


def check_stub_coverage(files: dict[str, ScannedFile]) -> list[Violation]:
    """Cross-file rule: pjrt/stub API parity + cfg-gate pairing."""
    out: list[Violation] = []
    pjrt = next((f for p, f in files.items() if p.endswith("rust/src/runtime/pjrt.rs")), None)
    stub = next((f for p, f in files.items() if p.endswith("rust/src/runtime/stub.rs")), None)
    if pjrt is not None and stub is not None:
        stub_fns = set(_PUB_FN_RE.findall(stub.masked))
        stub_types = set(_PUB_TYPE_RE.findall(stub.masked))
        for m in _PUB_FN_RE.finditer(pjrt.masked):
            name = m.group(1)
            if name not in stub_fns:
                out.append(
                    Violation(
                        "stub-coverage",
                        stub.path,
                        1,
                        f"`pub fn {name}` (pjrt.rs:{pjrt.masked.count(chr(10), 0, m.start()) + 1}) "
                        "has no stub counterpart — the offline build rots",
                    )
                )
        for m in _PUB_TYPE_RE.finditer(pjrt.masked):
            name = m.group(1)
            if name not in stub_types:
                out.append(
                    Violation(
                        "stub-coverage",
                        stub.path,
                        1,
                        f"`pub` type `{name}` (pjrt.rs:{pjrt.masked.count(chr(10), 0, m.start()) + 1}) "
                        "has no stub counterpart — the offline build rots",
                    )
                )
    for path, f in sorted(files.items()):
        if path.endswith("rust/src/runtime/pjrt.rs"):
            continue  # the gated module itself lives behind the gate in mod.rs
        positives = list(_CFG_PJRT_POS_RE.finditer(f.masked))
        if positives and not _CFG_PJRT_NEG_RE.search(f.masked):
            line = f.masked.count("\n", 0, positives[0].start()) + 1
            out.append(
                Violation(
                    "stub-coverage",
                    path,
                    line,
                    "`#[cfg(theseus_pjrt)]` without a `#[cfg(not(theseus_pjrt))]` sibling — "
                    "the offline build silently loses this item",
                )
            )
    return out


def check_all(files: dict[str, ScannedFile]) -> list[Violation]:
    out: list[Violation] = []
    for _, f in sorted(files.items()):
        for lineno, msg in f.suppression_errors:
            out.append(Violation("suppression", f.path, lineno, msg))
        out.extend(check_panic(f))
        out.extend(check_determinism(f))
        out.extend(check_loud_failure(f))
    out.extend(check_stub_coverage(files))
    return out
