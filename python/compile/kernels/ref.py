"""Pure-jnp correctness oracles for the Pallas kernels (pytest compares
kernel outputs against these — the core L1 correctness signal)."""

import jax.numpy as jnp


def mlp_layer_ref(x, w, b, relu=True):
    y = jnp.dot(x, w) + b[None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def scatter_add_ref(messages, idx, num_nodes):
    out = jnp.zeros((num_nodes, messages.shape[1]), dtype=messages.dtype)
    return out.at[idx].add(messages)


def gather_ref(nodes, idx):
    return nodes[idx]
