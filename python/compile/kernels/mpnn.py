"""L1: Pallas kernels for the GNN NoC-congestion estimator.

Two kernels cover the model's hot spots:

* :func:`mlp_layer` — tiled ``x @ w + b`` with optional ReLU, the workhorse
  behind every MLP in the network (feature generators, message/update
  functions, congestion head).
* :func:`scatter_add` — segment-sum of edge messages into node slots,
  expressed as one-hot-tile x message matmuls so the reduction runs on the
  MXU instead of a scalar scatter (DESIGN.md §Hardware-Adaptation).

Both are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret-mode lowers to plain HLO that the Rust
runtime executes. Correctness oracles live in :mod:`compile.kernels.ref`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes tuned for VMEM tiling (DESIGN.md §8): edge-dimension tiles of
# 128 keep every operand block under ~128 KB.
BLOCK_M = 128
BLOCK_N = 128


def _mlp_kernel(x_ref, w_ref, b_ref, o_ref, *, relu):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


def mlp_layer(x, w, b, relu=True):
    """``relu(x @ w + b)`` (or affine when ``relu=False``).

    x: f32[M, K]; w: f32[K, N]; b: f32[N]. M must be a multiple of BLOCK_M
    or small enough to be one block; K, N are kept whole per block (the
    model's K, N <= 80 fit VMEM trivially).
    """
    m, _k = x.shape
    _k2, n = w.shape
    block_m = BLOCK_M if m % BLOCK_M == 0 else m
    grid = (m // block_m,)
    return pl.pallas_call(
        functools.partial(_mlp_kernel, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, x.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((w.shape[0], n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)


def _scatter_kernel(msg_ref, idx_ref, o_ref, *, num_nodes):
    e_block = msg_ref.shape[0]
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    msg = msg_ref[...]  # [E_blk, H]
    idx = idx_ref[...]  # [E_blk]
    # One-hot tile [N, E_blk]: onehot[v, e] = (idx[e] == v). The reduction
    # onehot @ msg runs as a dense matmul (MXU-shaped on real hardware).
    node_ids = jax.lax.broadcasted_iota(jnp.int32, (num_nodes, e_block), 0)
    onehot = (node_ids == idx[None, :]).astype(jnp.float32)
    o_ref[...] += jnp.dot(onehot, msg, preferred_element_type=jnp.float32)


def scatter_add(messages, idx, num_nodes):
    """Segment-sum: ``out[idx[e]] += messages[e]``.

    messages: f32[E, H]; idx: i32[E]; returns f32[num_nodes, H]. Padded
    edges must carry zero messages (mask applied by the caller) — they then
    contribute zeros wherever their index points.
    """
    e, h = messages.shape
    block_e = BLOCK_M if e % BLOCK_M == 0 else e
    grid = (e // block_e,)
    return pl.pallas_call(
        functools.partial(_scatter_kernel, num_nodes=num_nodes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, h), lambda i: (i, 0)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
        ],
        # Every grid step accumulates into the same output block.
        out_specs=pl.BlockSpec((num_nodes, h), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_nodes, h), jnp.float32),
        interpret=True,
    )(messages, idx)


def gather(nodes, idx):
    """``nodes[idx]`` — plain jnp take (cheap, memory-bound; the MXU work
    lives in mlp_layer/scatter_add)."""
    return jnp.take(nodes, idx, axis=0)
