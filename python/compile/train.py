"""L2 training: fit the GNN congestion model on the CA-simulator dataset
(paper §VIII-A "GNN Training Setup").

Usage (invoked by `make artifacts`):
    python -m compile.train --data ../artifacts/noc_dataset.json \
                            --out  ../artifacts/gnn_params.npz

Hand-rolled Adam (no optax dependency); training uses the pure-jnp path
for speed, and the saved parameters are frozen into the Pallas-kernel AOT
graph by compile.aot (kernel-vs-ref equivalence is covered by pytest).
"""

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from . import features, model


def load_dataset(path):
    with open(path) as f:
        doc = json.load(f)
    feats, labels = [], []
    for obj in doc["samples"]:
        fe, y = features.sample_from_json(obj)
        feats.append(fe)
        labels.append(y)
    batch = {
        "node_feat": np.stack([f["node_feat"] for f in feats]),
        "edge_feat": np.stack([f["edge_feat"] for f in feats]),
        "src_idx": np.stack([f["src_idx"] for f in feats]),
        "dst_idx": np.stack([f["dst_idx"] for f in feats]),
        "edge_mask": np.stack([f["edge_mask"] for f in feats]),
        "y": np.stack(labels),
    }
    return batch


def split(batch, frac=0.85, seed=0):
    n = batch["y"].shape[0]
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    cut = max(int(n * frac), 1)
    tr = {k: v[idx[:cut]] for k, v in batch.items()}
    va = {k: v[idx[cut:]] for k, v in batch.items()} if cut < n else tr
    return tr, va


def adam_init(params):
    return {
        "m": {k: np.zeros_like(v) for k, v in params.items()},
        "v": {k: np.zeros_like(v) for k, v in params.items()},
        "t": 0,
    }


def make_train_step(lr=3e-3, b1=0.9, b2=0.999, eps=1e-8):
    grad_fn = jax.jit(jax.value_and_grad(lambda p, b: model.loss_fn(p, b)))

    def step(params, opt, batch):
        loss, grads = grad_fn(params, batch)
        opt["t"] += 1
        t = opt["t"]
        new_params = {}
        for k in params:
            g = np.asarray(grads[k])
            opt["m"][k] = b1 * opt["m"][k] + (1 - b1) * g
            opt["v"][k] = b2 * opt["v"][k] + (1 - b2) * g * g
            mhat = opt["m"][k] / (1 - b1**t)
            vhat = opt["v"][k] / (1 - b2**t)
            new_params[k] = np.asarray(params[k]) - lr * mhat / (np.sqrt(vhat) + eps)
        return new_params, opt, float(loss)

    return step


def minibatches(batch, bs, rng):
    n = batch["y"].shape[0]
    idx = rng.permutation(n)
    for i in range(0, n, bs):
        sel = idx[i : i + bs]
        yield {k: jnp.asarray(v[sel]) for k, v in batch.items()}


def eval_metrics(params, batch):
    """Masked MAE (cycles) and MAPE on loaded links."""
    fwd = jax.jit(lambda nf, ef, si, di, em: model.forward(params, nf, ef, si, di, em, use_pallas=False))
    abs_err, denom, ape, ape_n = 0.0, 0.0, 0.0, 0
    for i in range(batch["y"].shape[0]):
        pred = np.asarray(
            fwd(
                batch["node_feat"][i],
                batch["edge_feat"][i],
                batch["src_idx"][i],
                batch["dst_idx"][i],
                batch["edge_mask"][i],
            )
        )
        y = batch["y"][i]
        m = batch["edge_mask"][i] > 0
        abs_err += np.abs(pred[m] - y[m]).sum()
        denom += m.sum()
        loaded = m & (y > 0.5)
        if loaded.any():
            ape += (np.abs(pred[loaded] - y[loaded]) / y[loaded]).sum()
            ape_n += loaded.sum()
    mae = abs_err / max(denom, 1)
    mape = ape / max(ape_n, 1)
    return float(mae), float(mape)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--data", required=True)
    ap.add_argument("--out", required=True)
    ap.add_argument("--epochs", type=int, default=int(__import__("os").environ.get("THESEUS_GNN_EPOCHS", 60)))
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    t0 = time.time()
    batch = load_dataset(args.data)
    n = batch["y"].shape[0]
    print(f"loaded {n} samples from {args.data}")
    train, val = split(batch)

    params = init = model.init_params(args.seed)
    opt = adam_init(init)
    step = make_train_step()
    rng = np.random.default_rng(args.seed)

    best = None
    for epoch in range(args.epochs):
        losses = []
        for mb in minibatches(train, args.batch_size, rng):
            params, opt, loss = step(params, opt, mb)
            losses.append(loss)
        if epoch % 10 == 0 or epoch == args.epochs - 1:
            mae, mape = eval_metrics(params, val)
            print(
                f"epoch {epoch:3d} loss {np.mean(losses):.4f} "
                f"val MAE {mae:.3f} cyc, MAPE(loaded) {mape*100:.1f}%"
            )
            if best is None or mae < best[0]:
                best = (mae, {k: np.asarray(v) for k, v in params.items()})

    mae, params = best
    np.savez(args.out, **params)
    print(f"saved {args.out} (val MAE {mae:.3f} cycles) in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
