"""L2: the GNN NoC-congestion estimator (paper §VI-C, Fig. 6).

Architecture, as in the paper:
  * Feature generator — MLPs projecting node features x_v and edge
    features x_e to initial hidden states h_v^0, h_e^0.
  * Graph convolution — T rounds of message passing on the topology graph
    G *and its reverse* (upstream contention + downstream backpressure,
    following Noception [30]).
  * Congestion predictor — MLP over Concat(h_u^T, h_v^T, h_e^0)
    predicting the mean channel waiting time y_e (Eq. 5).

All dense compute routes through the L1 Pallas kernels
(:mod:`compile.kernels.mpnn`); set ``use_pallas=False`` to run the pure-jnp
reference path (used to cross-check the kernels end-to-end).
"""

import numpy as np

import jax
import jax.numpy as jnp

from .features import E_MAX, F_E, F_N, N_MAX
from .kernels import mpnn, ref

HIDDEN = 32
T_ROUNDS = 3


def _glorot(rng, shape):
    fan_in, fan_out = shape
    scale = np.sqrt(2.0 / (fan_in + fan_out))
    return rng.normal(0.0, scale, size=shape).astype(np.float32)


def init_params(seed=0):
    """Initialize all weights (numpy dict, later frozen into the AOT HLO)."""
    rng = np.random.default_rng(seed)
    h = HIDDEN
    p = {
        # Feature generators.
        "node_w": _glorot(rng, (F_N, h)),
        "node_b": np.zeros(h, np.float32),
        "edge_w": _glorot(rng, (F_E, h)),
        "edge_b": np.zeros(h, np.float32),
    }
    # Per-round message and update MLPs (weights shared across rounds is
    # also common; per-round matches Noception and trains better here).
    for t in range(T_ROUNDS):
        p[f"msg_w{t}"] = _glorot(rng, (2 * h, h))
        p[f"msg_b{t}"] = np.zeros(h, np.float32)
        p[f"upd_w{t}"] = _glorot(rng, (3 * h, h))
        p[f"upd_b{t}"] = np.zeros(h, np.float32)
    # Congestion predictor: Concat(h_u, h_v, h_e0) -> hidden -> 1.
    p["head_w1"] = _glorot(rng, (3 * h, h))
    p["head_b1"] = np.zeros(h, np.float32)
    p["head_w2"] = _glorot(rng, (h, 1))
    p["head_b2"] = np.zeros(1, np.float32)
    return p


def forward(params, node_feat, edge_feat, src_idx, dst_idx, edge_mask, use_pallas=True):
    """Predict per-edge mean waiting time ŷ (Eq. 5). Shapes are the padded
    statics from :mod:`compile.features`; returns f32[E_MAX]."""
    if use_pallas:
        mlp, scatter, gather = mpnn.mlp_layer, mpnn.scatter_add, mpnn.gather
    else:
        mlp, scatter, gather = ref.mlp_layer_ref, ref.scatter_add_ref, ref.gather_ref

    mask = edge_mask[:, None]
    h_v = mlp(node_feat, params["node_w"], params["node_b"])  # [N, H]
    h_e0 = mlp(edge_feat, params["edge_w"], params["edge_b"]) * mask  # [E, H]

    for t in range(T_ROUNDS):
        h_src = gather(h_v, src_idx)  # [E, H]
        h_dst = gather(h_v, dst_idx)
        # Forward messages (upstream contention): m_e = f(h_u, h_e).
        m_fwd = mlp(
            jnp.concatenate([h_src, h_e0], axis=1),
            params[f"msg_w{t}"],
            params[f"msg_b{t}"],
        ) * mask
        agg_fwd = scatter(m_fwd, dst_idx, N_MAX)
        # Reverse messages (downstream backpressure): same weights applied
        # on the reversed graph, as in the paper ("message passing is
        # conducted on both the original graph G and its reversed graph").
        m_rev = mlp(
            jnp.concatenate([h_dst, h_e0], axis=1),
            params[f"msg_w{t}"],
            params[f"msg_b{t}"],
        ) * mask
        agg_rev = scatter(m_rev, src_idx, N_MAX)
        h_v = mlp(
            jnp.concatenate([h_v, agg_fwd, agg_rev], axis=1),
            params[f"upd_w{t}"],
            params[f"upd_b{t}"],
        )

    h_u = gather(h_v, src_idx)
    h_w = gather(h_v, dst_idx)
    z = jnp.concatenate([h_u, h_w, h_e0], axis=1)  # [E, 3H]
    z = mlp(z, params["head_w1"], params["head_b1"])
    y = mlp(z, params["head_w2"], params["head_b2"], relu=False)[:, 0]
    # Waiting times are non-negative; softplus keeps gradients alive.
    y = jax.nn.softplus(y)
    return y * edge_mask


def loss_fn(params, batch, use_pallas=False):
    """Masked Huber loss on log1p(wait) — robust to the heavy congestion
    tail. batch = dict of stacked padded arrays + 'y'."""

    def one(nf, ef, si, di, em, y):
        pred = forward(params, nf, ef, si, di, em, use_pallas=use_pallas)
        t = jnp.log1p(y)
        p = jnp.log1p(pred)
        d = p - t
        huber = jnp.where(jnp.abs(d) < 1.0, 0.5 * d * d, jnp.abs(d) - 0.5)
        return jnp.sum(huber * em) / jnp.maximum(jnp.sum(em), 1.0)

    losses = jax.vmap(one)(
        batch["node_feat"],
        batch["edge_feat"],
        batch["src_idx"],
        batch["dst_idx"],
        batch["edge_mask"],
        batch["y"],
    )
    return jnp.mean(losses)


def forward_batched(params, node_feat, edge_feat, src_idx, dst_idx, edge_mask,
                    use_pallas=True):
    """vmap of :func:`forward` over a leading batch dimension — one
    independent padded slot per batch index. The Rust strategy sweep packs
    several candidate chunks per execute call through this entry point
    (rust/src/runtime/batch.rs); slots are fully independent, so batched
    and per-slot predictions agree."""

    def one(nf, ef, si, di, em):
        return forward(params, nf, ef, si, di, em, use_pallas=use_pallas)

    return jax.vmap(one)(node_feat, edge_feat, src_idx, dst_idx, edge_mask)


def input_shapes():
    """AOT export signature (order matters — the Rust runtime feeds
    arguments positionally)."""
    return [
        jax.ShapeDtypeStruct((N_MAX, F_N), jnp.float32),  # node_feat
        jax.ShapeDtypeStruct((E_MAX, F_E), jnp.float32),  # edge_feat
        jax.ShapeDtypeStruct((E_MAX,), jnp.int32),  # src_idx
        jax.ShapeDtypeStruct((E_MAX,), jnp.int32),  # dst_idx
        jax.ShapeDtypeStruct((E_MAX,), jnp.float32),  # edge_mask
    ]


def input_shapes_batched(batch):
    """AOT export signature with a leading batch dimension of `batch`
    (mirrored by `GnnMeta::batch` in rust/src/runtime/mod.rs)."""
    return [
        jax.ShapeDtypeStruct((batch,) + tuple(s.shape), s.dtype)
        for s in input_shapes()
    ]
