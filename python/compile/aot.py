"""AOT export: freeze the trained GNN (L2 + L1 Pallas kernels) into HLO
text for the Rust PJRT runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md and
DESIGN.md §1).

The export carries a leading batch dimension (``--batch``, default 8): the
Rust strategy sweep packs several candidate chunks per execute call
(rust/src/runtime/batch.rs) because the PJRT executable is thread-confined
and per-call dispatch dominates single-chunk inference. ``--batch 1``
keeps the legacy per-chunk signature; the slot count is recorded in the
``gnn_noc.meta.json`` sidecar (``batch``) so the runtime knows which
signature it loaded. The static shapes cut both ways: a batch-B executable
runs all B slots even for a single-chunk prediction, so when ``--batch``
exceeds 1 a per-chunk **sibling** (``*.chunk.hlo.txt`` + meta) is exported
alongside it — ``GnnModel::load_per_chunk_default`` serves
per-chunk-dominated callers (figure benches) from the sibling while the
DSE batcher keeps the batched artifact.

Usage (invoked by `make artifacts`):
    python -m compile.aot --params ../artifacts/gnn_params.npz \
                          --out    ../artifacts/gnn_noc.hlo.txt \
                          [--batch 8]
"""

import argparse
import json
import os

import numpy as np

import jax
from jax._src.lib import xla_client as xc

from . import features, model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(params, use_pallas=True, batch=1):
    """Lower forward(params frozen, padded inputs) to HLO text.

    ``batch > 1`` lowers the vmapped ``forward_batched`` over
    ``[batch, ...]``-shaped inputs; ``batch == 1`` keeps the legacy
    per-chunk signature (no leading dimension)."""
    frozen = {k: np.asarray(v) for k, v in params.items()}

    if batch > 1:

        def fn(node_feat, edge_feat, src_idx, dst_idx, edge_mask):
            return (
                model.forward_batched(
                    frozen, node_feat, edge_feat, src_idx, dst_idx, edge_mask,
                    use_pallas=use_pallas,
                ),
            )

        shapes = model.input_shapes_batched(batch)
    else:

        def fn(node_feat, edge_feat, src_idx, dst_idx, edge_mask):
            return (
                model.forward(
                    frozen, node_feat, edge_feat, src_idx, dst_idx, edge_mask,
                    use_pallas=use_pallas,
                ),
            )

        shapes = model.input_shapes()

    lowered = jax.jit(fn).lower(*shapes)
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", required=True)
    ap.add_argument("--out", required=True)
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the pure-jnp reference path instead of the "
                         "Pallas kernels (debug only)")
    ap.add_argument("--batch", type=int, default=8,
                    help="leading batch dimension of the export: padded "
                         "chunk slots per execute call (1 = legacy "
                         "per-chunk signature)")
    args = ap.parse_args()
    if args.batch < 1:
        ap.error("--batch must be >= 1")
    if not args.out.endswith(".hlo.txt"):
        # The meta-sidecar and sibling paths are derived by replacing the
        # '.hlo.txt' suffix; any other suffix would make every derived
        # path collapse onto --out and silently overwrite the export.
        ap.error("--out must end in .hlo.txt")

    params = dict(np.load(args.params))

    def export(out_path, batch):
        text = lower_model(params, use_pallas=not args.no_pallas, batch=batch)
        with open(out_path, "w") as f:
            f.write(text)
        # Sidecar metadata so the Rust runtime can verify schema
        # compatibility (and learn the executable's batch capacity).
        meta = {
            "n_max": features.N_MAX,
            "e_max": features.E_MAX,
            "f_n": features.F_N,
            "f_e": features.F_E,
            "batch": batch,
            "hidden": model.HIDDEN,
            "rounds": model.T_ROUNDS,
            "inputs": ["node_feat", "edge_feat", "src_idx", "dst_idx", "edge_mask"],
            "pallas": not args.no_pallas,
        }
        with open(out_path.replace(".hlo.txt", ".meta.json"), "w") as f:
            json.dump(meta, f, indent=2)
        print(f"wrote {len(text)} chars of HLO to {out_path} (batch={batch})")

    export(args.out, args.batch)
    sibling = args.out.replace(".hlo.txt", ".chunk.hlo.txt")
    if args.batch > 1:
        # Per-chunk sibling: single-slot callers (figure benches) would
        # otherwise pay the full batch-slot program per prediction.
        export(sibling, 1)
    else:
        # A --batch 1 re-export IS the per-chunk artifact; drop any stale
        # sibling from an earlier batched export or the Rust
        # load_per_chunk_default would silently prefer outdated weights.
        for stale in (sibling, sibling.replace(".hlo.txt", ".meta.json")):
            if os.path.exists(stale):
                os.remove(stale)
                print(f"removed stale sibling {stale}")


if __name__ == "__main__":
    main()
