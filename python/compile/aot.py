"""AOT export: freeze the trained GNN (L2 + L1 Pallas kernels) into HLO
text for the Rust PJRT runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md and
DESIGN.md §1).

Usage (invoked by `make artifacts`):
    python -m compile.aot --params ../artifacts/gnn_params.npz \
                          --out    ../artifacts/gnn_noc.hlo.txt
"""

import argparse
import json

import numpy as np

import jax
from jax._src.lib import xla_client as xc

from . import features, model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(params, use_pallas=True):
    """Lower forward(params frozen, padded inputs) to HLO text."""
    frozen = {k: np.asarray(v) for k, v in params.items()}

    def fn(node_feat, edge_feat, src_idx, dst_idx, edge_mask):
        return (
            model.forward(
                frozen, node_feat, edge_feat, src_idx, dst_idx, edge_mask,
                use_pallas=use_pallas,
            ),
        )

    lowered = jax.jit(fn).lower(*model.input_shapes())
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", required=True)
    ap.add_argument("--out", required=True)
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the pure-jnp reference path instead of the "
                         "Pallas kernels (debug only)")
    args = ap.parse_args()

    params = dict(np.load(args.params))
    text = lower_model(params, use_pallas=not args.no_pallas)
    with open(args.out, "w") as f:
        f.write(text)

    # Sidecar metadata so the Rust runtime can verify schema compatibility.
    meta = {
        "n_max": features.N_MAX,
        "e_max": features.E_MAX,
        "f_n": features.F_N,
        "f_e": features.F_E,
        "hidden": model.HIDDEN,
        "rounds": model.T_ROUNDS,
        "inputs": ["node_feat", "edge_feat", "src_idx", "dst_idx", "edge_mask"],
        "pallas": not args.no_pallas,
    }
    with open(args.out.replace(".hlo.txt", ".meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {len(text)} chars of HLO to {args.out}")


if __name__ == "__main__":
    main()
