"""Shared GNN feature/padding schema (L2 build path).

This module is the single source of truth for how a NoC sample (mesh h x w,
per-link byte loads, per-node injected bytes, zero-load cycle estimate T0)
becomes the padded tensors the GNN consumes. The Rust runtime
(rust/src/runtime/features.rs) mirrors this EXACTLY — any change here must
be reflected there (guarded by the golden test in
python/tests/test_features.py and rust's runtime::features tests).

Padded shapes (static for AOT):
    node_feat  f32[N_MAX, F_N]
    edge_feat  f32[E_MAX, F_E]
    src_idx    i32[E_MAX]
    dst_idx    i32[E_MAX]
    edge_mask  f32[E_MAX]
Edge enumeration order: for node in row-major order, for dir in
(E, W, S, N) — i.e. dense ``link_index`` order with invalid (out-of-mesh)
links skipped.
"""

import numpy as np

N_MAX = 256  # 16 x 16 mesh
E_MAX = 1024  # >= 2*2*16*15 = 960 directed links
F_N = 5
F_E = 4
NUM_DIRS = 4
# (drow, dcol) for E, W, S, N — matches rust compiler::routing::Dir.
DIR_OFFSETS = ((0, 1), (0, -1), (1, 0), (-1, 0))


def mesh_edges(h, w):
    """Valid directed links in link_index order: [(src_node, dst_node, dense_idx)]."""
    edges = []
    for r in range(h):
        for c in range(w):
            node = r * w + c
            for d, (dr, dc) in enumerate(DIR_OFFSETS):
                rr, cc = r + dr, c + dc
                if 0 <= rr < h and 0 <= cc < w:
                    edges.append((node, rr * w + cc, node * NUM_DIRS + d))
    return edges


def build_features(h, w, noc_bw_bits, node_bytes, link_bytes, t0_cycles):
    """Build padded GNN inputs from one sample.

    node_bytes: [h*w] bytes injected per node.
    link_bytes: [h*w*4] bytes per dense link index.
    Returns dict of padded numpy arrays.
    """
    n = h * w
    assert n <= N_MAX, f"mesh {h}x{w} exceeds N_MAX"
    flit_bytes = max(noc_bw_bits / 8.0, 1.0)
    t0 = max(float(t0_cycles), 1.0)

    node_feat = np.zeros((N_MAX, F_N), dtype=np.float32)
    for r in range(h):
        for c in range(w):
            i = r * w + c
            inject = node_bytes[i] / flit_bytes / t0
            node_feat[i] = (
                inject,
                1.0,  # active
                # max(extent - 1, 1): one normalizer expression on both
                # sides of the mirror (rust runtime::features::coord_norm)
                # — a 1xN strip degenerates the divisor, pinned by the
                # golden strip test on each side.
                r / max(h - 1, 1),
                c / max(w - 1, 1),
                1.0,  # bias
            )

    edges = mesh_edges(h, w)
    assert len(edges) <= E_MAX
    edge_feat = np.zeros((E_MAX, F_E), dtype=np.float32)
    src_idx = np.zeros(E_MAX, dtype=np.int32)
    dst_idx = np.zeros(E_MAX, dtype=np.int32)
    edge_mask = np.zeros(E_MAX, dtype=np.float32)
    bw_norm = np.log2(max(noc_bw_bits, 32) / 32.0) / 7.0
    for e, (s, d, dense) in enumerate(edges):
        rho = link_bytes[dense] / flit_bytes / t0  # demand utilization
        edge_feat[e] = (rho, bw_norm, 1.0, 1.0)
        src_idx[e] = s
        dst_idx[e] = d
        edge_mask[e] = 1.0
    return {
        "node_feat": node_feat,
        "edge_feat": edge_feat,
        "src_idx": src_idx,
        "dst_idx": dst_idx,
        "edge_mask": edge_mask,
        "edges": edges,
    }


def build_labels(h, w, link_wait):
    """Padded per-edge regression targets (mean waiting cycles per flit)."""
    edges = mesh_edges(h, w)
    y = np.zeros(E_MAX, dtype=np.float32)
    for e, (_, _, dense) in enumerate(edges):
        y[e] = link_wait[dense]
    return y


def sample_from_json(obj):
    """Decode one dataset sample (dict parsed from noc_dataset.json)."""
    h = int(obj["height"])
    w = int(obj["width"])
    feats = build_features(
        h,
        w,
        int(obj["noc_bw_bits"]),
        obj["node_bytes"],
        obj["link_bytes"],
        obj["t0_cycles"],
    )
    y = build_labels(h, w, obj["link_wait"])
    return feats, y
