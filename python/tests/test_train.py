"""Training-pipeline smoke tests: the Adam loop reduces loss on a tiny
dataset, and the saved-params -> AOT flow round-trips."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import features, model, train


def tiny_batch(n_samples=6, seed=0):
    rng = np.random.default_rng(seed)
    feats, labels = [], []
    for i in range(n_samples):
        h, w = 3, 3 + (i % 2)
        nn = h * w
        f = features.build_features(
            h, w, 256,
            rng.uniform(0, 2e5, size=nn),
            rng.uniform(0, 2e5, size=nn * 4),
            t0_cycles=5e3,
        )
        # Synthetic congestion: wait grows with the edge load feature.
        y = np.zeros(features.E_MAX, np.float32)
        act = f["edge_mask"] > 0
        y[act] = 3.0 * f["edge_feat"][act][:, 0] + 0.1
        feats.append(f)
        labels.append(y)
    return {
        "node_feat": np.stack([f["node_feat"] for f in feats]),
        "edge_feat": np.stack([f["edge_feat"] for f in feats]),
        "src_idx": np.stack([f["src_idx"] for f in feats]),
        "dst_idx": np.stack([f["dst_idx"] for f in feats]),
        "edge_mask": np.stack([f["edge_mask"] for f in feats]),
        "y": np.stack(labels),
    }


def test_adam_reduces_loss():
    batch = tiny_batch()
    params = model.init_params(0)
    opt = train.adam_init(params)
    step = train.make_train_step(lr=5e-3)
    jb = {k: jnp.asarray(v) for k, v in batch.items()}
    _, _, loss0 = step(params, opt, jb)
    params2 = params
    for _ in range(20):
        params2, opt, loss = step(params2, opt, jb)
    assert loss < loss0 * 0.8, f"{loss} !< {loss0}"


def test_eval_metrics_shapes():
    batch = tiny_batch(3)
    params = model.init_params(1)
    mae, mape = train.eval_metrics(params, batch)
    assert mae >= 0.0
    assert mape >= 0.0


def test_split_partitions():
    batch = tiny_batch(6)
    tr, va = train.split(batch, frac=0.5, seed=1)
    assert tr["y"].shape[0] + va["y"].shape[0] == 6


def test_params_npz_roundtrip(tmp_path):
    params = model.init_params(0)
    p = tmp_path / "params.npz"
    np.savez(p, **params)
    loaded = dict(np.load(p))
    assert set(loaded) == set(params)
    for k in params:
        np.testing.assert_array_equal(loaded[k], params[k])
