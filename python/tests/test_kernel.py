"""L1 correctness: Pallas kernels vs pure-jnp oracle (the core signal),
with hypothesis sweeping shapes and value distributions."""

import numpy as np
import pytest

# Offline CI images may lack hypothesis; skip (loudly) instead of erroring
# the whole collection.
pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import mpnn, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(rng, *shape):
    return rng.normal(0, 1, size=shape).astype(np.float32)


# --- mlp_layer ---------------------------------------------------------

@given(
    m=st.sampled_from([1, 7, 128, 256, 384]),
    k=st.sampled_from([4, 5, 32, 72, 96]),
    n=st.sampled_from([1, 8, 32, 64]),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_mlp_layer_matches_ref(m, k, n, relu, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    got = mpnn.mlp_layer(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), relu=relu)
    want = ref.mlp_layer_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), relu=relu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_mlp_layer_large_values():
    rng = np.random.default_rng(0)
    x = (rng.normal(0, 100, size=(128, 16))).astype(np.float32)
    w = rand(rng, 16, 8)
    b = rand(rng, 8)
    got = mpnn.mlp_layer(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    want = ref.mlp_layer_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)


# --- scatter_add -------------------------------------------------------

@given(
    e=st.sampled_from([8, 128, 256, 1024]),
    h=st.sampled_from([1, 8, 32]),
    n=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_scatter_add_matches_ref(e, h, n, seed):
    rng = np.random.default_rng(seed)
    msg = rand(rng, e, h)
    idx = rng.integers(0, n, size=e).astype(np.int32)
    got = mpnn.scatter_add(jnp.asarray(msg), jnp.asarray(idx), n)
    want = ref.scatter_add_ref(jnp.asarray(msg), jnp.asarray(idx), n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_scatter_add_collisions():
    # All edges hit node 3: output[3] = column sums.
    msg = np.arange(64 * 4, dtype=np.float32).reshape(64, 4)
    idx = np.full(64, 3, dtype=np.int32)
    got = np.asarray(mpnn.scatter_add(jnp.asarray(msg), jnp.asarray(idx), 8))
    np.testing.assert_allclose(got[3], msg.sum(axis=0), rtol=1e-6)
    assert np.all(got[[0, 1, 2, 4, 5, 6, 7]] == 0.0)


def test_scatter_add_zero_messages_are_neutral():
    # Padded edges (zero messages) must not perturb the result wherever
    # their index points.
    rng = np.random.default_rng(1)
    msg = rand(rng, 128, 8)
    msg[100:] = 0.0
    idx = rng.integers(0, 32, size=128).astype(np.int32)
    idx2 = idx.copy()
    idx2[100:] = 0  # repoint padding at node 0
    a = np.asarray(mpnn.scatter_add(jnp.asarray(msg), jnp.asarray(idx), 32))
    b = np.asarray(mpnn.scatter_add(jnp.asarray(msg), jnp.asarray(idx2), 32))
    np.testing.assert_allclose(a, b, rtol=1e-6)


# --- gather ------------------------------------------------------------

def test_gather_matches_ref():
    rng = np.random.default_rng(2)
    nodes = rand(rng, 64, 16)
    idx = rng.integers(0, 64, size=256).astype(np.int32)
    got = np.asarray(mpnn.gather(jnp.asarray(nodes), jnp.asarray(idx)))
    want = np.asarray(ref.gather_ref(jnp.asarray(nodes), jnp.asarray(idx)))
    np.testing.assert_allclose(got, want)


def test_dtype_is_f32():
    rng = np.random.default_rng(3)
    out = mpnn.mlp_layer(
        jnp.asarray(rand(rng, 128, 8)),
        jnp.asarray(rand(rng, 8, 4)),
        jnp.asarray(rand(rng, 4)),
    )
    assert out.dtype == jnp.float32
