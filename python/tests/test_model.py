"""L2 model tests: shapes, masking, pallas/ref path equivalence, and the
AOT lowering contract."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import features, model


def synthetic_inputs(h=4, w=5, seed=0, noc_bw=512):
    rng = np.random.default_rng(seed)
    n = h * w
    node_bytes = rng.uniform(0, 1e5, size=n)
    link_bytes = rng.uniform(0, 1e5, size=n * 4)
    return features.build_features(h, w, noc_bw, node_bytes, link_bytes, t0_cycles=5e3)


def test_feature_shapes():
    f = synthetic_inputs()
    assert f["node_feat"].shape == (features.N_MAX, features.F_N)
    assert f["edge_feat"].shape == (features.E_MAX, features.F_E)
    assert f["src_idx"].shape == (features.E_MAX,)
    assert f["edge_mask"].sum() == len(features.mesh_edges(4, 5))


def test_mesh_edges_structure():
    # 3x3 mesh: 2*2*3*2 = 24 directed links.
    edges = features.mesh_edges(3, 3)
    assert len(edges) == 24
    # All endpoints valid, no self-loops, dense indices unique.
    dense = set()
    for s, d, i in edges:
        assert 0 <= s < 9 and 0 <= d < 9 and s != d
        assert i not in dense
        dense.add(i)


def test_forward_shapes_and_mask():
    f = synthetic_inputs()
    params = model.init_params(0)
    y = np.asarray(
        model.forward(
            params,
            jnp.asarray(f["node_feat"]),
            jnp.asarray(f["edge_feat"]),
            jnp.asarray(f["src_idx"]),
            jnp.asarray(f["dst_idx"]),
            jnp.asarray(f["edge_mask"]),
            use_pallas=False,
        )
    )
    assert y.shape == (features.E_MAX,)
    assert np.all(y >= 0.0), "waiting times must be non-negative"
    # Padded edges predict exactly zero.
    pad = f["edge_mask"] == 0
    assert np.all(y[pad] == 0.0)


def test_pallas_and_ref_paths_agree():
    f = synthetic_inputs(seed=3)
    params = model.init_params(1)
    args = (
        jnp.asarray(f["node_feat"]),
        jnp.asarray(f["edge_feat"]),
        jnp.asarray(f["src_idx"]),
        jnp.asarray(f["dst_idx"]),
        jnp.asarray(f["edge_mask"]),
    )
    y_ref = np.asarray(model.forward(params, *args, use_pallas=False))
    y_pal = np.asarray(model.forward(params, *args, use_pallas=True))
    np.testing.assert_allclose(y_pal, y_ref, rtol=1e-4, atol=1e-5)


def test_loss_decreases_on_tiny_problem():
    # A couple of gradient steps on one synthetic batch must reduce loss.
    import jax

    f = synthetic_inputs(seed=5)
    y = np.abs(np.random.default_rng(5).normal(2.0, 1.0, size=features.E_MAX)).astype(
        np.float32
    ) * f["edge_mask"]
    batch = {
        "node_feat": np.stack([f["node_feat"]]),
        "edge_feat": np.stack([f["edge_feat"]]),
        "src_idx": np.stack([f["src_idx"]]),
        "dst_idx": np.stack([f["dst_idx"]]),
        "edge_mask": np.stack([f["edge_mask"]]),
        "y": np.stack([y]),
    }
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    params = model.init_params(0)
    grad_fn = jax.jit(jax.value_and_grad(lambda p: model.loss_fn(p, batch)))
    l0, g = grad_fn(params)
    params2 = {k: params[k] - 0.05 * np.asarray(g[k]) for k in params}
    l1, _ = grad_fn(params2)
    assert float(l1) < float(l0), f"{l1} !< {l0}"


def test_aot_lowering_produces_hlo_text():
    from compile import aot

    params = model.init_params(0)
    text = aot.lower_model(params, use_pallas=False)
    assert "HloModule" in text
    assert len(text) > 1000


def test_forward_batched_matches_per_slot():
    # The batched export contract: each slot of forward_batched must equal
    # forward on that slot alone (the Rust batcher relies on slot
    # independence to keep batched == per-chunk predictions).
    params = model.init_params(2)
    slots = [synthetic_inputs(h=3, w=4, seed=s) for s in range(3)]
    batch = {
        k: jnp.asarray(np.stack([f[k] for f in slots]))
        for k in ("node_feat", "edge_feat", "src_idx", "dst_idx", "edge_mask")
    }
    y_batched = np.asarray(
        model.forward_batched(
            params,
            batch["node_feat"],
            batch["edge_feat"],
            batch["src_idx"],
            batch["dst_idx"],
            batch["edge_mask"],
            use_pallas=False,
        )
    )
    assert y_batched.shape == (3, features.E_MAX)
    for i, f in enumerate(slots):
        y_one = np.asarray(
            model.forward(
                params,
                jnp.asarray(f["node_feat"]),
                jnp.asarray(f["edge_feat"]),
                jnp.asarray(f["src_idx"]),
                jnp.asarray(f["dst_idx"]),
                jnp.asarray(f["edge_mask"]),
                use_pallas=False,
            )
        )
        np.testing.assert_allclose(y_batched[i], y_one, rtol=1e-5, atol=1e-6)


def test_batched_aot_lowering_has_leading_batch_dim():
    from compile import aot

    params = model.init_params(0)
    text = aot.lower_model(params, use_pallas=False, batch=4)
    assert "HloModule" in text
    # The entry signature must carry the [4, N_MAX, F_N] node tensor.
    assert f"f32[4,{features.N_MAX},{features.F_N}]" in text
    shapes = model.input_shapes_batched(4)
    assert shapes[0].shape == (4, features.N_MAX, features.F_N)
    assert shapes[2].shape == (4, features.E_MAX)
