"""theseus-lint test suite: tokenizer fidelity, per-rule fixtures, the
suppression contract, the ratchet baseline, and an end-to-end run over
the real repository against the committed baseline.
"""

import json
import os

from theseus_lint import RULES, check_all, mask_source, scan_file
from theseus_lint import baseline as bl
from theseus_lint.cli import run, scan_tree

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

LIB = "rust/src/eval/foo.rs"  # a non-exempt library path for fixtures


def violations(text, path=LIB):
    f = scan_file(path, text, set(RULES))
    return check_all({path: f})


def rules_hit(text, path=LIB):
    return sorted({v.rule for v in violations(text, path)})


# ---------------------------------------------------------------- tokenizer


def test_mask_blanks_strings_and_comments_preserving_shape():
    src = 'let s = "call .unwrap() now"; // also .unwrap()\nlet x = 1;\n'
    masked = mask_source(src)
    assert len(masked) == len(src)
    assert masked.count("\n") == src.count("\n")
    assert ".unwrap()" not in masked
    assert "let x = 1;" in masked


def test_string_and_comment_tokens_do_not_trip_rules():
    assert violations('fn f() { let s = "x.unwrap()"; }\n') == []
    assert violations("fn f() {} // panic! is documented here\n") == []
    assert violations("/* block comment: thread_rng() /* nested */ still */ fn f() {}\n") == []


def test_raw_strings_masked_at_any_hash_depth():
    assert violations('fn f() { let s = r"a.unwrap()"; }\n') == []
    assert violations('fn f() { let s = r#"a.unwrap() "quoted" more"#; }\n') == []
    assert violations('fn f() { let b = br#"bytes.unwrap()"#; }\n') == []


def test_char_literals_masked_lifetimes_untouched():
    # '"' must not open a string; 'a> must parse as a lifetime.
    src = "fn g<'a>(x: &'a str) -> char { let q = '\"'; let s = \"x.unwrap()\"; q }\n"
    assert violations(src) == []


def test_real_tokens_still_found_next_to_masked_ones():
    src = 'fn f() { let s = "safe.unwrap()"; s.parse().unwrap(); }\n'
    vs = violations(src)
    assert [v.rule for v in vs] == ["panic"]
    assert vs[0].line == 1


# -------------------------------------------------------------- test regions


def test_cfg_test_mod_is_exempt_but_code_outside_is_not():
    src = (
        "pub fn lib() { x.unwrap(); }\n"
        "#[cfg(test)]\n"
        "mod tests {\n"
        "    #[test]\n"
        "    fn t() { y.unwrap(); z.expect(\"ok\"); panic!(\"boom\"); }\n"
        "}\n"
    )
    vs = violations(src)
    assert len(vs) == 1 and vs[0].line == 1


def test_test_attr_fn_is_exempt_even_outside_mod_tests():
    src = "#[test]\nfn t() { x.unwrap(); }\npub fn lib() { y.unwrap(); }\n"
    vs = violations(src)
    assert len(vs) == 1 and vs[0].line == 3


def test_braces_inside_test_strings_do_not_desync_the_region():
    src = (
        "#[cfg(test)]\n"
        "mod tests {\n"
        '    fn t() { let s = "}}}"; x.unwrap(); }\n'
        "}\n"
        "pub fn lib() { y.unwrap(); }\n"
    )
    vs = violations(src)
    assert len(vs) == 1 and vs[0].line == 5


def test_cfg_test_out_of_line_mod_marks_nothing():
    # `#[cfg(test)] mod tests;` — the file itself is exempt by path.
    src = "#[cfg(test)]\nmod tests;\npub fn lib() { x.unwrap(); }\n"
    vs = violations(src)
    assert len(vs) == 1 and vs[0].line == 3


# -------------------------------------------------------------------- rules


def test_panic_rule_catches_the_whole_family():
    src = (
        "pub fn f() {\n"
        "    a.unwrap();\n"
        '    b.expect("m");\n'
        '    panic!("x");\n'
        '    unreachable!("y");\n'
        "    todo!();\n"
        "    unimplemented!();\n"
        "}\n"
    )
    vs = violations(src)
    assert [v.line for v in vs] == [2, 3, 4, 5, 6, 7]
    assert {v.rule for v in vs} == {"panic"}


def test_panic_rule_exempts_main_and_frozen_oracle():
    src = "pub fn f() { x.unwrap(); }\n"
    assert violations(src, "rust/src/main.rs") == []
    assert violations(src, "rust/src/noc_sim/reference.rs") == []


def test_determinism_rule_flags_clocks_and_nondeterministic_rng():
    assert rules_hit("fn f() { let t = Instant::now(); }\n") == ["determinism"]
    assert rules_hit("fn f() { let t = SystemTime::now(); }\n") == ["determinism"]
    assert rules_hit("fn f() { let mut r = thread_rng(); }\n") == ["determinism"]
    # Seeded in-tree Rng stays legal everywhere.
    assert violations("fn f() { let mut r = Rng::new(seed); }\n") == []


def test_hashmap_banned_only_in_artifact_modules():
    src = "use std::collections::HashMap;\npub fn f() { let m: HashMap<u32, u32> = HashMap::new(); }\n"
    assert violations(src, "rust/src/eval/foo.rs") == []
    vs = violations(src, "rust/src/coordinator/foo.rs")
    assert vs and all(v.rule == "determinism" for v in vs)
    assert violations(src, "rust/src/util/json.rs")
    assert violations(src, "rust/src/figures/fig99.rs")


def test_loud_failure_flags_env_var_and_eprintln_outside_owners():
    src = 'fn f() { let v = env::var("X"); eprintln!("fallback"); }\n'
    vs = violations(src)
    assert [v.rule for v in vs] == ["loud-failure", "loud-failure"]
    assert violations(src, "rust/src/util/cli.rs") == []


# ------------------------------------------------------------- suppressions


def test_same_line_suppression_with_reason_is_honored():
    src = "pub fn f() { x.unwrap() } // lint: allow(panic) guarded by is_some above\n"
    assert violations(src) == []


def test_standalone_suppression_covers_next_line_only():
    src = (
        "// lint: allow(panic) slot written by exactly one worker\n"
        "pub fn f() { x.unwrap(); }\n"
        "pub fn g() { y.unwrap(); }\n"
    )
    vs = violations(src)
    assert len(vs) == 1 and vs[0].line == 3


def test_suppression_is_rule_scoped():
    src = "// lint: allow(panic) a panic proof, not a clock proof\nfn f() { let t = Instant::now(); }\n"
    assert rules_hit(src) == ["determinism"]


def test_suppression_without_reason_is_a_fatal_error():
    src = "pub fn f() { x.unwrap() } // lint: allow(panic)\n"
    vs = violations(src)
    assert any(v.rule == "suppression" and "no reason" in v.message for v in vs)


def test_suppression_with_unknown_rule_is_a_fatal_error():
    src = "pub fn f() {} // lint: allow(speed) because\n"
    vs = violations(src)
    assert any(v.rule == "suppression" and "unknown rule" in v.message for v in vs)


# ------------------------------------------------------------ stub coverage


PJRT_FIXTURE = (
    "pub struct GnnModel;\n"
    "impl GnnModel {\n"
    "    pub fn load() {}\n"
    "    pub fn predict_padded_batch() {}\n"
    "}\n"
)
STUB_MISSING_BATCH = "pub struct GnnModel;\nimpl GnnModel {\n    pub fn load() {}\n}\n"


def scan_pair(stub_text):
    files = {
        "rust/src/runtime/pjrt.rs": scan_file("rust/src/runtime/pjrt.rs", PJRT_FIXTURE, set(RULES)),
        "rust/src/runtime/stub.rs": scan_file("rust/src/runtime/stub.rs", stub_text, set(RULES)),
    }
    return [v for v in check_all(files) if v.rule == "stub-coverage"]


def test_stub_coverage_flags_missing_counterpart():
    vs = scan_pair(STUB_MISSING_BATCH)
    assert len(vs) == 1 and "predict_padded_batch" in vs[0].message


def test_stub_coverage_clean_when_api_parallel():
    assert scan_pair(PJRT_FIXTURE) == []


def test_positive_cfg_gate_requires_not_sibling():
    lone = "#[cfg(theseus_pjrt)]\npub fn only_online() {}\n"
    vs = violations(lone)
    assert any(v.rule == "stub-coverage" for v in vs)
    paired = lone + "#[cfg(not(theseus_pjrt))]\npub fn only_offline() {}\n"
    assert violations(paired) == []


# ----------------------------------------------------------------- baseline


def test_baseline_compare_flags_growth_and_unlocked_shrink():
    vs = violations("pub fn f() { x.unwrap(); y.unwrap(); }\n")
    current = bl.counts_of(vs)
    assert bl.compare(current, current, vs) == []
    above = bl.compare(current, {"panic": {LIB: 1}}, vs)
    assert any("new debt" in p for p in above)
    below = bl.compare(current, {"panic": {LIB: 3}}, vs)
    assert any("not locked in" in p for p in below)


def test_check_no_growth_reports_grown_entries_only():
    assert bl.check_no_growth({"panic": {LIB: 2}}, {"panic": {LIB: 2}}) == []
    assert bl.check_no_growth({"panic": {LIB: 3}}, {"panic": {LIB: 2}}) != []
    assert bl.check_no_growth({"panic": {}}, {"panic": {LIB: 2}}) == []


# -------------------------------------------------------------- end to end


def write_tree(root, files):
    for rel, text in files.items():
        p = root / "rust" / "src" / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)


def test_e2e_repo_scan_matches_committed_baseline():
    """The gate ci_check.sh runs: the real tree against the real baseline."""
    assert os.path.isfile(os.path.join(REPO, "scripts", "lint_baseline.json"))
    assert run(["--root", REPO]) == 0


def test_e2e_committed_baseline_is_strictly_below_initial_scan():
    with open(os.path.join(REPO, "scripts", "lint_baseline.json")) as fh:
        doc = json.load(fh)
    initial = doc["_meta"]["initial_scan"]
    accepted = bl.totals(doc["rules"])
    assert sum(accepted.values()) < sum(initial.values())
    assert accepted["panic"] < initial["panic"]


def test_e2e_injected_violation_fails(tmp_path):
    write_tree(tmp_path, {"eval/ok.rs": "pub fn f() -> u32 { 1 }\n"})
    base = tmp_path / "baseline.json"
    argv = ["--root", str(tmp_path), "--baseline", str(base)]
    assert run(argv + ["--update-baseline"]) == 0
    assert run(argv) == 0
    write_tree(tmp_path, {"eval/bad.rs": "pub fn f() { x.unwrap(); }\n"})
    assert run(argv) == 1


def test_e2e_no_baseline_requires_clean_tree(tmp_path):
    write_tree(tmp_path, {"eval/bad.rs": "pub fn f() { x.unwrap(); }\n"})
    assert run(["--root", str(tmp_path), "--baseline", str(tmp_path / "nope.json")]) == 1
    write_tree(tmp_path, {"eval/bad.rs": "pub fn f() -> u32 { 1 }\n"})
    assert run(["--root", str(tmp_path), "--baseline", str(tmp_path / "nope.json")]) == 0


def test_e2e_stale_baseline_fails_until_update(tmp_path):
    write_tree(tmp_path, {"eval/f.rs": "pub fn f() { x.unwrap(); }\n"})
    base = tmp_path / "baseline.json"
    argv = ["--root", str(tmp_path), "--baseline", str(base)]
    assert run(argv + ["--update-baseline"]) == 0
    # Fix the violation: the stale (now too-large) baseline must fail loudly.
    write_tree(tmp_path, {"eval/f.rs": "pub fn f() -> u32 { 1 }\n"})
    assert run(argv) == 1
    assert run(argv + ["--update-baseline"]) == 0
    assert run(argv) == 0


def test_e2e_update_refuses_growth_and_preserves_initial_scan(tmp_path):
    write_tree(tmp_path, {"eval/f.rs": "pub fn f() { x.unwrap(); }\n"})
    base = tmp_path / "baseline.json"
    argv = ["--root", str(tmp_path), "--baseline", str(base)]
    assert run(argv + ["--update-baseline"]) == 0
    initial = json.loads(base.read_text())["_meta"]["initial_scan"]
    assert initial["panic"] == 1
    write_tree(tmp_path, {"eval/f.rs": "pub fn f() { x.unwrap(); y.unwrap(); }\n"})
    assert run(argv + ["--update-baseline"]) == 1  # growth refused
    assert run(argv + ["--update-baseline", "--allow-baseline-growth"]) == 0
    doc = json.loads(base.read_text())
    assert doc["rules"]["panic"]["rust/src/eval/f.rs"] == 2
    assert doc["_meta"]["initial_scan"] == initial  # first scan survives resets


def test_e2e_malformed_suppression_fails_even_with_baseline_headroom(tmp_path):
    write_tree(
        tmp_path,
        {"eval/f.rs": "pub fn f() { x.unwrap() } // lint: allow(panic)\n"},
    )
    base = tmp_path / "baseline.json"
    base.write_text(json.dumps({"rules": {"panic": {"rust/src/eval/f.rs": 5}}}))
    assert run(["--root", str(tmp_path), "--baseline", str(base)]) == 1


def test_scan_tree_sees_every_rs_file_under_rust_src(tmp_path):
    write_tree(
        tmp_path,
        {"a.rs": "pub fn a() {}\n", "deep/nested/b.rs": "pub fn b() {}\n"},
    )
    (tmp_path / "rust" / "src" / "notes.txt").write_text("x.unwrap()")
    files = scan_tree(str(tmp_path))
    assert sorted(files) == ["rust/src/a.rs", "rust/src/deep/nested/b.rs"]
