"""Make `compile` importable regardless of pytest's invocation directory.

`scripts/ci_check.sh` runs `pytest python/tests -q` from the repo root;
the test modules import `from compile import ...`, which lives in
`python/compile`. Putting `python/` on sys.path here keeps both
invocations (`cd python && pytest tests` and root-level `pytest
python/tests`) working.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
