"""Feature-schema tests, including the golden values pinned on the Rust
side (rust/src/runtime/features.rs) — the two implementations must stay in
lockstep or the GNN sees garbage at DSE time."""

import json
import os

import numpy as np
import pytest

from compile import features


def test_golden_mesh_edges_2x2():
    # Must match rust runtime::features::tests::golden_matches_python_schema.
    assert features.mesh_edges(2, 2) == [
        (0, 1, 0),
        (0, 2, 2),
        (1, 0, 5),
        (1, 3, 6),
        (2, 3, 8),
        (2, 0, 11),
        (3, 2, 13),
        (3, 1, 15),
    ]


def test_mesh_edge_count_formula():
    for h, w in [(3, 3), (4, 7), (16, 16), (1, 5)]:
        assert len(features.mesh_edges(h, w)) == 2 * (2 * h * w - h - w)


def test_golden_strip_mesh_1xn():
    # 1xN strip — the degenerate height where the coordinate normalizer
    # max(h - 1, 1) is most fragile. Rust pins the same numbers in
    # runtime::features::tests::golden_matches_python_schema; a drift on
    # either side of the mirror fails loudly.
    assert features.mesh_edges(1, 5) == [
        (0, 1, 0),
        (1, 2, 4),
        (1, 0, 5),
        (2, 3, 8),
        (2, 1, 9),
        (3, 4, 12),
        (3, 2, 13),
        (4, 3, 17),
    ]
    f = features.build_features(
        1, 5, 512, np.zeros(5), np.zeros(5 * 4), t0_cycles=1e3
    )
    # Row coordinate pins to exactly 0 (0 / max(1-1, 1)); column sweeps
    # 0..1 in quarters (c / max(5-1, 1)).
    assert np.all(f["node_feat"][:5, 2] == 0.0)
    np.testing.assert_array_equal(
        f["node_feat"][:5, 3], np.asarray([0.0, 0.25, 0.5, 0.75, 1.0], np.float32)
    )


def test_padding_invariants():
    n = 3 * 4
    f = features.build_features(
        3, 4, 512, np.arange(n) * 1e3, np.arange(n * 4) * 10.0, t0_cycles=1e4
    )
    # Inactive node rows are all zero.
    assert np.all(f["node_feat"][n:] == 0.0)
    # Masked edges contribute index 0 (safe scatter target).
    pad = f["edge_mask"] == 0
    assert np.all(f["src_idx"][pad] == 0)
    # Active edges all have the bias feature set.
    act = f["edge_mask"] == 1
    assert np.all(f["edge_feat"][act][:, 3] == 1.0)


def test_feature_normalization_uses_t0():
    nb = np.full(4, 64_000.0)
    lb = np.zeros(16)
    a = features.build_features(2, 2, 512, nb, lb, t0_cycles=1_000.0)
    b = features.build_features(2, 2, 512, nb, lb, t0_cycles=2_000.0)
    # inject = bytes / flit_bytes / t0 -> halving t0 doubles the feature.
    assert a["node_feat"][0, 0] == pytest.approx(2 * b["node_feat"][0, 0])
    # 64 KB over 64 B flits over 1000 cycles = 1 flit/cycle.
    assert a["node_feat"][0, 0] == pytest.approx(1.0)


def test_dataset_sample_roundtrip_if_built():
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "noc_dataset.json")
    if not os.path.exists(path):
        pytest.skip("artifacts/noc_dataset.json not built")
    with open(path) as f:
        doc = json.load(f)
    assert len(doc["samples"]) > 0
    feats, y = features.sample_from_json(doc["samples"][0])
    assert feats["node_feat"].shape == (features.N_MAX, features.F_N)
    assert y.shape == (features.E_MAX,)
    # Labels only on real edges.
    assert np.all(y[feats["edge_mask"] == 0] == 0.0)
